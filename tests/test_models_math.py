"""Model math correctness: attention equivalences, SSD vs sequential scan,
MoE dispatch conservation, prefill-vs-decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.models import build_model
from repro.models.attention import chunked_attention, decode_attention, naive_attention
from repro.models.moe import expert_capacity, moe_ffn, moe_init
from repro.models.ssm import ssd_forward

RNG = np.random.default_rng(7)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


class TestAttention:
    @pytest.mark.parametrize("kvh", [1, 2, 4])
    def test_chunked_equals_naive(self, kvh):
        b, s, h, hd = 2, 160, 4, 32
        q = _randn((b, s, h, hd))
        k = _randn((b, s, kvh, hd))
        v = _randn((b, s, kvh, hd))
        a = naive_attention(q, k, v, causal=True)
        c = chunked_attention(q, k, v, causal=True, q_chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)

    def test_sliding_window_masks_history(self):
        b, s, h, hd = 1, 64, 1, 16
        q, k, v = _randn((b, s, h, hd)), _randn((b, s, 1, hd)), _randn((b, s, 1, hd))
        full = naive_attention(q, k, v, causal=True)
        win = naive_attention(q, k, v, causal=True, window=8)
        # early positions (history < window) identical; late differ
        np.testing.assert_allclose(np.asarray(full)[:, :8], np.asarray(win)[:, :8],
                                   atol=1e-6)
        assert not np.allclose(np.asarray(full)[:, -1], np.asarray(win)[:, -1])

    def test_decode_matches_full_attention_last_token(self):
        b, s, h, hd, kvh = 1, 12, 4, 16, 2
        q = _randn((b, s, h, hd))
        k = _randn((b, s, kvh, hd))
        v = _randn((b, s, kvh, hd))
        full = naive_attention(q, k, v, causal=True)
        # decode path: last token vs cache of all s tokens
        out = decode_attention(q[:, -1:], k, v, cache_len=jnp.asarray([s]))
        np.testing.assert_allclose(np.asarray(out)[:, 0], np.asarray(full)[:, -1],
                                   atol=2e-5)


class TestSSD:
    def _sequential_ref(self, xh, dt, a, bmat, cmat):
        b, s, h, p = xh.shape
        n = bmat.shape[-1]
        state = np.zeros((b, h, p, n), np.float64)
        ys = np.zeros((b, s, h, p), np.float64)
        da = np.exp(-(np.asarray(dt) * np.asarray(a)[None, None]))
        for t in range(s):
            state = state * da[:, t][..., None, None] + np.einsum(
                "bh,bn,bhp->bhpn", np.asarray(dt[:, t]), np.asarray(bmat[:, t]),
                np.asarray(xh[:, t], np.float64))
            ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(cmat[:, t]), state)
        return ys

    @pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (64, 64)])
    def test_chunked_matches_sequential(self, s, chunk):
        b, h, p, n = 2, 3, 4, 5
        xh = _randn((b, s, h, p))
        dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, s, h)), jnp.float32)
        a = jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
        bmat = _randn((b, s, n))
        cmat = _randn((b, s, n))
        y, _ = ssd_forward(xh, dt, a, bmat, cmat, chunk)
        want = self._sequential_ref(xh, dt, a, bmat, cmat)
        np.testing.assert_allclose(np.asarray(y), want, rtol=2e-3, atol=2e-3)

    def test_decay_reduces_memory(self):
        """With large a (fast decay), early tokens stop influencing late ys."""
        b, s, h, p, n = 1, 32, 1, 2, 2
        xh = _randn((b, s, h, p))
        xh2 = xh.at[:, 0].set(100.0)   # perturb first token
        dtv = jnp.full((b, s, h), 0.5)
        bmat, cmat = _randn((b, s, n)), _randn((b, s, n))
        a_fast = jnp.asarray([8.0])
        y1, _ = ssd_forward(xh, dtv, a_fast, bmat, cmat, 8)
        y2, _ = ssd_forward(xh2, dtv, a_fast, bmat, cmat, 8)
        late_diff = float(jnp.abs(y1[:, -1] - y2[:, -1]).max())
        assert late_diff < 1e-3


class TestMoE:
    def _cfg(self):
        return get_arch("granite-moe-1b-a400m").reduced()

    def test_capacity_formula(self):
        cfg = self._cfg()
        cap = expert_capacity(64, cfg)
        assert cap >= cfg.top_k
        assert cap >= int(64 * cfg.top_k / cfg.num_experts)

    def test_moe_output_finite_and_shaped(self):
        cfg = self._cfg()
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = _randn((2, 16, cfg.d_model), jnp.bfloat16, 0.5)
        y, aux = moe_ffn(p, x, cfg)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y, np.float32)).all()
        assert float(aux) > 0  # load-balance loss positive

    def test_dropped_tokens_get_zero_output(self):
        """With capacity_factor→0 every token overflows → output ≈ 0."""
        import dataclasses
        cfg = dataclasses.replace(self._cfg(), capacity_factor=1e-9)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = _randn((1, 8, cfg.d_model), jnp.bfloat16, 0.5)
        y, _ = moe_ffn(p, x, cfg)
        # capacity floors at top_k, so not exactly zero; but bounded
        assert np.isfinite(np.asarray(y, np.float32)).all()


class TestPrefillDecodeConsistency:
    @pytest.mark.parametrize("arch", ["qwen2-72b", "gemma2-27b", "whisper-large-v3",
                                      "mamba2-2.7b", "zamba2-1.2b"])
    def test_decode_reproduces_forward_logits(self, arch):
        """Feeding tokens one-by-one through decode_step must produce the
        same final-position logits as the teacher-forced forward pass."""
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        b, s = 1, 8
        tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        extras = {}
        if cfg.family == "vlm":
            extras["vision_embeds"] = jnp.zeros((b, cfg.vision_seq, cfg.d_model),
                                                jnp.bfloat16)
            batch.update(extras)
        if cfg.family == "encdec":
            frames = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            batch["frames"] = frames
            from repro.models.encdec import encode
            extras["memory"] = encode(params, frames, cfg)
        full_logits = model.forward(params, batch)          # (b, s, vocab)
        cache = model.init_cache(b, 32)
        step = jax.jit(model.decode_step)
        for t in range(s):
            dbatch = {"token": tokens[:, t:t + 1], **extras}
            logits, cache = step(params, dbatch, cache)
        # SSM archs run chunk-parallel SSD in training and a sequential
        # state recurrence in decode — same math, different bf16
        # summation order — so they get a looser tolerance.
        tol = 5e-2 if cfg.ssm_state else 2e-2
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, -1]),
            rtol=tol, atol=tol)

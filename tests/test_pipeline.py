"""Pipeline-layer tests: predictor/bank serialization round-trips,
ProfileStore warm-cache semantics, LatencyService fingerprint LRU,
OpGraph adjacency index, and the MAPE-guard regression.

These run without optional deps (no hypothesis) so the predictor
families stay covered even where tests/test_predictors.py is skipped.
"""
import json

import numpy as np
import pytest

from repro.core.composition import PredictorBank, mape
from repro.core.ir import OpGraph
from repro.core.predictors import load_predictor, make_predictor
from repro.core.profiler import DeviceSetting, ProfileSession
from repro.pipeline import LatencyService, PredictorHub, ProfileStore

SETTING = DeviceSetting("cpu_f32", "float32", "op_by_op")

FAST_KW = {
    "lasso": {},
    "rf": {"n_trees": 4},
    "gbdt": {"n_stages": 25},
    "mlp": {"max_epochs": 50},
}


def roofline_data(n=80, d=5, seed=0):
    """Synthetic roofline labels: max(flops/peak, bytes/bw) + dispatch."""
    rng = np.random.default_rng(seed)
    x = np.abs(rng.standard_normal((n, d))) * np.array([1e9, 1e6, 64, 64, 3])
    flops, nbytes = x[:, 0], x[:, 1]
    y = np.maximum(flops / 50e9, nbytes / 10e9) + 5e-6
    return x, y


def tiny_graph(name="t", ch=4):
    g = OpGraph(name)
    x0 = g.add_input((1, 4, 4, ch))
    (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, ch)],
                     {"kernel_h": 3, "kernel_w": 3, "stride": 1, "groups": 1})
    (e1,) = g.add_op("elementwise", [c1], [(1, 4, 4, ch)], {"ew_kind": "add"})
    (m1,) = g.add_op("mean", [e1], [(1, ch)])
    g.mark_output(m1)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Predictor serialization (satellite: save/load round-trip per family)
# ---------------------------------------------------------------------------

class TestPredictorRoundTrip:
    @pytest.mark.parametrize("family", ["lasso", "rf", "gbdt", "mlp"])
    def test_roundtrip_identical_predictions(self, family):
        x, y = roofline_data()
        m = make_predictor(family, **FAST_KW[family]).fit(x, y)
        blob = json.dumps(m.to_json())          # through actual JSON text
        m2 = load_predictor(json.loads(blob))
        assert np.array_equal(m.predict(x), m2.predict(x))

    def test_bank_roundtrip(self):
        x, y = roofline_data()
        bank = PredictorBank(setting="cpu_f32", overhead=1e-4,
                             overhead_per_kernel=2e-6, op_sum_scale=1.1)
        bank.predictors["conv2d"] = make_predictor("gbdt", n_stages=25).fit(x, y)
        bank.predictors["mean"] = make_predictor("lasso").fit(x, y)
        bank2 = PredictorBank.from_json(json.loads(json.dumps(bank.to_json())))
        assert bank2.setting == bank.setting
        assert bank2.overhead == bank.overhead
        assert bank2.overhead_per_kernel == bank.overhead_per_kernel
        assert bank2.op_sum_scale == bank.op_sum_scale
        for t in bank.predictors:
            assert np.array_equal(bank.predictors[t].predict(x),
                                  bank2.predictors[t].predict(x))


# ---------------------------------------------------------------------------
# MAPE guard (satellite regression)
# ---------------------------------------------------------------------------

class TestMapeGuard:
    def test_zero_and_tiny_negative_labels_bounded(self):
        x, y = roofline_data(n=20)
        m = make_predictor("lasso").fit(x, y)
        bad_y = np.array([0.0, -1e-300, 1e-300] + [1.0] * 17)
        v = m.mape(x, bad_y)
        assert np.isfinite(v)
        # Each clamped term is bounded by |pred - y| / 1e-12.
        bound = np.max(np.abs(m.predict(x) - bad_y)) / 1e-12
        assert v <= bound

    def test_composition_mape_clamped(self):
        # Old np.where(y == 0, ...) guard let -1e-300 divide unprotected
        # (→ ~1e300); the clamp bounds it to |diff| / 1e-12.
        v = mape([-1e-300], [1.0])
        assert np.isfinite(v) and v <= 1.0 / 1e-12
        assert mape([2.0], [2.0]) == 0.0
        assert mape([4.0], [2.0]) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# OpGraph adjacency index (satellite: O(1) consumers/producer)
# ---------------------------------------------------------------------------

class TestAdjacencyIndex:
    def test_matches_linear_scan(self):
        g = tiny_graph()
        for tid in g.tensors:
            assert g.consumers(tid) == [n for n in g.nodes if tid in n.inputs]
            assert g.producer(tid) == next(
                (n for n in g.nodes if tid in n.outputs), None)

    def test_invalidated_on_add_op(self):
        g = tiny_graph()
        out = g.output_ids[0]
        assert g.consumers(out) == []          # builds the index
        (e2,) = g.add_op("elementwise", [out], [(1, 4)], {"ew_kind": "neg"})
        assert [n.op_id for n in g.consumers(out)] == [g.nodes[-1].op_id]
        assert g.producer(e2) is g.nodes[-1]

    def test_duplicate_input_listed_once(self):
        g = OpGraph("dup")
        x0 = g.add_input((1, 4, 4, 4))
        g.add_op("elementwise", [x0, x0], [(1, 4, 4, 4)], {"ew_kind": "mul"})
        assert len(g.consumers(x0)) == 1


# ---------------------------------------------------------------------------
# ProfileStore (tentpole: persistent read-through/write-back cache)
# ---------------------------------------------------------------------------

class TestProfileStore:
    def fast_session(self, **kw):
        return ProfileSession(warmup=0, inner=1, repeats=1,
                              e2e_inner=1, e2e_repeats=1, **kw)

    def test_warm_store_measures_nothing(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        g = tiny_graph()
        s1 = self.fast_session(store=ProfileStore(path))
        rec1 = s1.profile_graph(g, SETTING)
        assert s1.measured_ops == 3 and s1.measured_graphs == 1

        # Fresh process-equivalent: new session, store reloaded from disk.
        s2 = self.fast_session(store=ProfileStore(path))
        rec2 = s2.profile_graph(g, SETTING)
        assert s2.measured_ops == 0 and s2.measured_graphs == 0
        assert rec2.e2e_s == rec1.e2e_s
        assert [o.latency_s for o in rec2.ops] == [o.latency_s for o in rec1.ops]

    def test_shared_signatures_skip_measurement(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        s1 = self.fast_session(store=ProfileStore(path))
        s1.profile_graph(tiny_graph("a"), SETTING)
        n = s1.measured_ops
        # A *different* graph with identical op configs: warm store, new
        # session → zero new measurements (per-signature reuse).
        s2 = self.fast_session(store=ProfileStore(path))
        s2.profile_graph(tiny_graph("b"), SETTING)
        assert n == 3 and s2.measured_ops == 0

    def test_op_axis_shared_across_modes(self, tmp_path):
        store = ProfileStore(str(tmp_path / "store.jsonl"))
        s = self.fast_session(store=store)
        s.profile_graph(tiny_graph(), SETTING)
        gpu = DeviceSetting("gpu_f32", "float32", "fused_groups")
        # Same dtype → op measurements shared between executor modes
        # (the fused graph here differs only in node signatures it needs).
        assert store.get_op(gpu, store.arch_records(SETTING)[0].ops[0].signature)

    def test_in_memory_store_api(self):
        store = ProfileStore()          # no path: same API, no persistence
        s = self.fast_session(store=store)
        s.profile_graph(tiny_graph(), SETTING)
        assert len(store) == 3
        x, y = store.op_table(SETTING, "conv2d")
        assert x.shape[0] == 1 and y.shape == (1,)
        assert store.op_types(SETTING) == ["conv2d", "elementwise", "mean"]


# ---------------------------------------------------------------------------
# PredictorHub + LatencyService (tentpole)
# ---------------------------------------------------------------------------

def _profiled_store(tmp_path, n=6):
    """A store with n size-varied graphs profiled under SETTING."""
    store = ProfileStore(str(tmp_path / "store.jsonl"))
    session = ProfileSession(warmup=0, inner=1, repeats=1,
                             e2e_inner=1, e2e_repeats=1, store=store)
    graphs = [tiny_graph(f"g{i}", ch=4 * (i + 1)) for i in range(n)]
    for g in graphs:
        session.profile_graph(g, SETTING)
    return store, session, graphs


class TestHubAndService:
    def test_train_save_load_roundtrip(self, tmp_path):
        store, _, graphs = _profiled_store(tmp_path)
        hub = PredictorHub(str(tmp_path / "hub"))
        bank = hub.train(store, SETTING, "gbdt", hparams={"n_stages": 20},
                         min_samples=2)
        hub2 = PredictorHub.load(str(tmp_path / "hub"))
        bank2 = hub2.get(SETTING, "gbdt")
        assert bank2 is not None
        g = graphs[0]
        assert bank2.predict_graph(g) == bank.predict_graph(g)

    def test_multi_family_training_reuses_dataset(self, tmp_path):
        store, _, graphs = _profiled_store(tmp_path)
        hub = PredictorHub()
        b1 = hub.train(store, SETTING, "lasso", min_samples=2)
        # Second family on the unchanged store hits the dataset-assembly
        # cache (regression: this used to crash with UnboundLocalError).
        b2 = hub.train(store, SETTING, "gbdt", hparams={"n_stages": 10},
                       min_samples=2)
        assert len(hub) == 2
        assert sorted(b1.predictors) == sorted(b2.predictors)

    def test_predict_e2e_cache_and_batch(self, tmp_path):
        store, session, graphs = _profiled_store(tmp_path)
        svc = LatencyService.build(graphs, SETTING, session=session,
                                   predictor="gbdt",
                                   hparams={"n_stages": 20})
        # The build re-used the session: nothing was measured twice.
        r1 = svc.predict_e2e(graphs[0])
        assert not r1.from_cache and r1.e2e_s > 0
        assert r1.num_ops == 3 and len(r1.per_op) == 3
        r2 = svc.predict_e2e(graphs[0])
        assert r2.from_cache and r2.e2e_s == r1.e2e_s
        assert svc.cache_info()["hits"] == 1

        svc.clear_cache()
        batch = svc.predict_batch(graphs)
        singles = [svc.predict_e2e(g) for g in graphs]
        for b, s in zip(batch, singles):
            assert s.from_cache            # batch populated the LRU
            assert b.e2e_s == s.e2e_s

    def test_retrain_invalidates_cache(self, tmp_path):
        store, session, graphs = _profiled_store(tmp_path)
        svc = LatencyService.build(graphs, SETTING, session=session,
                                   predictor="gbdt",
                                   hparams={"n_stages": 20})
        svc.predict_e2e(graphs[0])
        assert svc.predict_e2e(graphs[0]).from_cache
        # Retrain with different hparams → next query must not serve the
        # stale bank's cached report.
        svc.hub.train(store, SETTING, "gbdt", hparams={"n_stages": 5},
                      min_samples=2)
        r = svc.predict_e2e(graphs[0])
        assert not r.from_cache

    def test_lru_eviction(self, tmp_path):
        store, session, graphs = _profiled_store(tmp_path)
        svc = LatencyService.build(graphs, SETTING, session=session,
                                   predictor="lasso", cache_size=2)
        for g in graphs[:3]:
            svc.predict_e2e(g)
        assert svc.cache_info()["size"] == 2
        assert not svc.predict_e2e(graphs[0]).from_cache   # evicted

    def test_report_json(self, tmp_path):
        store, session, graphs = _profiled_store(tmp_path)
        svc = LatencyService.build(graphs, SETTING, session=session,
                                   predictor="lasso")
        d = svc.predict_e2e(graphs[0]).to_json()
        json.dumps(d)                      # serializable
        assert d["setting"] == "float32/op_by_op"
        assert len(d["per_op"]) == d["num_kernels"] == 3

    def test_missing_bank_raises(self, tmp_path):
        store, session, graphs = _profiled_store(tmp_path)
        svc = LatencyService.build(graphs, SETTING, session=session,
                                   predictor="lasso")
        with pytest.raises(KeyError):
            svc.predict_e2e(graphs[0],
                            DeviceSetting("cpu_int8", "int8", "op_by_op"))


# ---------------------------------------------------------------------------
# ServeEngine wiring (predicted step latency)
# ---------------------------------------------------------------------------

class _StubModel:
    """Minimal decode-capable model for engine wiring tests."""

    def init_cache(self, slots, max_len):
        return {"pos": 0}

    def decode_step(self, params, batch, cache):
        tok = batch["token"]
        import jax.numpy as jnp
        logits = jnp.tile(jnp.arange(8.0), (tok.shape[0], 1)) + tok
        return logits, {"pos": cache["pos"] + 1}


class TestServeEngineWiring:
    def test_predicted_step_latency(self, tmp_path):
        from repro.serving import ServeEngine

        store, session, graphs = _profiled_store(tmp_path)
        svc = LatencyService.build(graphs, SETTING, session=session,
                                   predictor="lasso")
        eng = ServeEngine(_StubModel(), params={}, batch_slots=2, max_len=16,
                          latency_service=svc, step_graph=graphs[0],
                          latency_setting=SETTING)
        assert eng.predicted_step_s is not None and eng.predicted_step_s > 0
        assert eng.estimate_request_s(4, 8) == pytest.approx(
            eng.predicted_step_s * 11)
        eng.submit(np.array([1, 2, 3]), max_new_tokens=2)
        done = eng.run(max_steps=10)
        assert len(done) == 1
        stats = eng.stats()
        assert stats["steps"] > 0 and stats["measured_step_s"] > 0
        assert stats["predicted_step_s"] == eng.predicted_step_s

    def test_engine_without_service_unchanged(self):
        from repro.serving import ServeEngine

        eng = ServeEngine(_StubModel(), params={}, batch_slots=2, max_len=16)
        assert eng.predicted_step_s is None
        assert eng.estimate_request_s(4, 8) is None

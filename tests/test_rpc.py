"""`repro.rpc` — serving layer: protocol, batcher, server, client.

Covers the v1 wire format (round-trips, typed error envelopes,
unknown-version rejection, committed golden files so drift fails
loudly), deterministic micro-batching under an injected clock
(flush-by-size, flush-by-deadline, fairness, admission control, cache
short-circuit), the threaded socket server + pipelined client
end-to-end (bit-identical to in-process `predict_e2e`), the
search-front endpoint, and `ServeEngine` taking its decode-step
estimate over the wire.  Everything runs on the deterministic
cost-model session; the thread-stress side lives in
tests/test_concurrency.py.
"""
import json
import os

import numpy as np
import pytest

from repro.core.dataset import synthetic_graphs
from repro.core.ir import OpGraph
from repro.core.nas_space import NASSpaceConfig, sample_architecture
from repro.core.profiler import DeviceSetting
from repro.pipeline import LatencyService, PredictorHub, ProfileStore
from repro.pipeline.service import PredictionReport
from repro.rpc import protocol
from repro.rpc.batcher import BatchPolicy, ManualClock, MicroBatcher
from repro.rpc.client import LatencyClient
from repro.rpc.protocol import (PROTOCOL_VERSION, Request, Response, RPCError,
                                decode_request, decode_response,
                                encode_request, encode_response)
from repro.rpc.server import LatencyRPCServer
from repro.search import DeviceBudget, SearchConfig, SearchEngine, SearchReport
from repro.transfer import CostModelProfileSession

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")
SOURCE = DeviceSetting("cpu_f32", "float32", "op_by_op")
SPACE = NASSpaceConfig(resolution=16)


@pytest.fixture(scope="module")
def served():
    """Cost-model-profiled store + trained hub + service."""
    store = ProfileStore()
    session = CostModelProfileSession(store=store, seed=3)
    graphs = synthetic_graphs(8, resolution=16)
    for g in graphs:
        session.profile_graph(g, SOURCE)
    hub = PredictorHub()
    hub.train(store, SOURCE, "gbdt", hparams={"n_stages": 20}, min_samples=3)
    hub.train(store, SOURCE, "lasso", min_samples=3)   # second batch group
    svc = LatencyService(hub, default_setting=SOURCE, predictor="gbdt")
    e2e = [store.get_arch(SOURCE, g.fingerprint()).e2e_s for g in graphs]
    return {"store": store, "hub": hub, "service": svc,
            "budget_s": float(np.median(e2e))}


@pytest.fixture(scope="module")
def live(served):
    """A started TCP server + connected client over a generous-wait
    batcher (50 ms) so pipelined sends reliably coalesce."""
    server = LatencyRPCServer(
        served["service"],
        policy=BatchPolicy(max_batch=8, max_wait_ticks=50, max_queue=256))
    host, port = server.start()
    client = LatencyClient(host, port, timeout=30.0)
    yield {"server": server, "client": client, **served}
    client.close()
    server.stop()


def graphs_for(seeds):
    return [sample_architecture(s, SPACE) for s in seeds]


# ---------------------------------------------------------------------------
# Protocol: round-trips, validation, error envelopes
# ---------------------------------------------------------------------------

class TestProtocol:
    def test_request_roundtrip(self):
        req = Request(id="r1", method="predict",
                      params={"graph": {"x": 1}, "setting": "float32/op_by_op"})
        again = decode_request(encode_request(req))
        assert again == req

    def test_response_roundtrip_ok_and_error(self):
        ok = Response(id="a", ok=True, result={"banks": []})
        again = decode_response(encode_response(ok))
        assert again.ok and again.result == {"banks": []} and again.id == "a"
        err = Response(id="b", ok=False,
                       error=RPCError(protocol.E_OVERLOADED, "full"))
        back = decode_response(encode_response(err))
        assert not back.ok
        assert back.error.code == protocol.E_OVERLOADED
        assert back.error.retryable          # overloaded defaults retryable
        assert back.error.message == "full"

    def test_unknown_version_rejected(self):
        line = json.dumps({"v": PROTOCOL_VERSION + 1, "id": "x",
                           "method": "stats"})
        with pytest.raises(RPCError) as ei:
            decode_request(line)
        assert ei.value.code == protocol.E_UNKNOWN_VERSION
        with pytest.raises(RPCError):
            decode_response(json.dumps({"v": 0, "id": "x", "ok": True,
                                        "result": {}}))

    @pytest.mark.parametrize("line", [
        "{oops", "42", json.dumps({"id": "x", "method": "m"}),
        json.dumps({"v": 1, "method": "m"}),
        json.dumps({"v": 1, "id": True, "method": "m"}),
        json.dumps({"v": 1, "id": "x", "method": 7}),
        json.dumps({"v": 1, "id": "x", "method": "m", "params": "no"}),
    ])
    def test_bad_requests_typed(self, line):
        with pytest.raises(RPCError) as ei:
            decode_request(line)
        assert ei.value.code in (protocol.E_BAD_REQUEST,
                                 protocol.E_UNKNOWN_VERSION)

    def test_setting_from_wire(self):
        s = protocol.setting_from_wire("sim:float32/op_by_op")
        assert (s.device, s.dtype, s.mode) == ("sim", "float32", "op_by_op")
        assert protocol.setting_key_of("sim:float32/op_by_op") == \
            "sim:float32/op_by_op"
        d = protocol.setting_from_wire(
            {"name": "x", "dtype": "int8", "mode": "op_by_op"})
        assert protocol.setting_key_of(d) == "int8/op_by_op"
        for bad in ("nope", "a/b/c", 7, {"dtype": "f32"}):
            with pytest.raises(RPCError):
                protocol.setting_from_wire(bad)

    def test_graph_from_wire_validates(self):
        g = sample_architecture(0, SPACE)
        clone = protocol.graph_from_wire(g.to_json())
        assert clone.fingerprint() == g.fingerprint()
        with pytest.raises(RPCError) as ei:
            protocol.graph_from_wire({"name": "broken"})
        assert ei.value.code == protocol.E_BAD_GRAPH
        with pytest.raises(RPCError):
            protocol.graph_from_wire("not an object")

    def test_report_wire_roundtrip_bit_exact(self, served):
        rep = served["service"].predict_e2e(sample_architecture(50, SPACE))
        clone = PredictionReport.from_json(
            json.loads(json.dumps(rep.to_json())))
        assert clone == rep


# ---------------------------------------------------------------------------
# Golden files: committed wire bytes must survive decode→encode unchanged
# ---------------------------------------------------------------------------

class TestGolden:
    def test_requests_canonical(self):
        with open(os.path.join(GOLDEN, "rpc_requests.jsonl")) as f:
            lines = [l.strip() for l in f if l.strip()]
        assert len(lines) >= 6
        seen = set()
        for line in lines:
            req = decode_request(line)
            seen.add(req.method)
            assert encode_request(req) == line
        assert seen == set(protocol.METHODS)

    def test_responses_canonical(self):
        with open(os.path.join(GOLDEN, "rpc_responses.jsonl")) as f:
            lines = [l.strip() for l in f if l.strip()]
        codes = set()
        for line in lines:
            resp = decode_response(line)
            if not resp.ok:
                codes.add(resp.error.code)
            assert encode_response(resp) == line
        assert {protocol.E_OVERLOADED, protocol.E_UNKNOWN_METHOD,
                protocol.E_BAD_GRAPH, protocol.E_INTERNAL} <= codes

    def test_golden_graph_payload_decodes(self):
        with open(os.path.join(GOLDEN, "rpc_requests.jsonl")) as f:
            for line in f:
                req = decode_request(line)
                if "graph" in req.params:
                    g = protocol.graph_from_wire(req.params["graph"])
                    assert isinstance(g, OpGraph) and g.num_ops() == 1

    def test_invalid_lines_rejected_with_committed_codes(self):
        with open(os.path.join(GOLDEN, "rpc_invalid.jsonl")) as f:
            cases = [json.loads(l) for l in f if l.strip()]
        assert cases
        for case in cases:
            with pytest.raises(RPCError) as ei:
                decode_request(case["line"])
            assert ei.value.code == case["code"], case

    def test_prediction_report_golden(self):
        with open(os.path.join(GOLDEN, "prediction_report.json")) as f:
            committed = json.load(f)
        rep = PredictionReport(
            graph_name="golden_net", fingerprint="0123456789abcdef",
            setting="float32/op_by_op", predictor="gbdt", e2e_s=0.0125,
            per_op=(("conv2d", 0.01),), overhead_s=0.0025,
            num_ops=1, num_kernels=1)
        assert rep.to_json() == committed          # wire drift fails here
        assert PredictionReport.from_json(committed) == rep


# ---------------------------------------------------------------------------
# Micro-batcher: deterministic flush policy under the injected clock
# ---------------------------------------------------------------------------

class TestBatcher:
    def mk(self, served, **kw):
        clock = ManualClock()
        policy = BatchPolicy(**{"max_batch": 4, "max_wait_ticks": 2,
                                "max_queue": 64, **kw})
        b = MicroBatcher(served["service"], policy, clock=clock,
                         auto_start=False)
        return b, clock

    def test_flush_by_size_then_deadline(self, served):
        served["service"].clear_cache()
        b, clock = self.mk(served)
        gs = graphs_for(range(100, 110))
        futs = [b.submit(g) for g in gs]
        # Two full batches (8 requests) are due immediately; 2 wait.
        assert b.run_pending() == 8
        assert b.queued() == 2
        assert b.run_pending() == 0          # deadline not reached
        clock.advance(2)
        assert b.run_pending() == 2
        reports = [f.result(1) for f in futs]
        direct = [served["service"].predict_e2e(g) for g in gs]
        assert [r.e2e_s for r in reports] == [d.e2e_s for d in direct]
        assert [r.fingerprint for r in reports] == \
            [g.fingerprint() for g in gs]
        st = b.stats()
        assert st["answered"] == st["submitted"] == 10
        assert st["batches"] == 3 and st["max_batch_observed"] == 4

    def test_cache_short_circuit_skips_queue(self, served):
        b, clock = self.mk(served)
        g = graphs_for([120])[0]
        served["service"].predict_e2e(g)           # warm the report cache
        fut = b.submit(g)
        assert fut.done() and fut.result(0).from_cache
        assert b.queued() == 0
        assert b.stats()["short_circuits"] == 1

    def test_admission_control_overloaded(self, served):
        served["service"].clear_cache()
        b, clock = self.mk(served, max_queue=3)
        gs = graphs_for(range(130, 134))
        futs = [b.submit(g) for g in gs[:3]]
        with pytest.raises(RPCError) as ei:
            b.submit(gs[3])
        assert ei.value.code == protocol.E_OVERLOADED and ei.value.retryable
        assert b.stats()["rejected"] == 1
        assert b.flush_all() == 3
        assert all(f.result(1).e2e_s > 0 for f in futs)

    def test_group_fairness_one_batch_each(self, served, monkeypatch):
        """Two request groups (gbdt vs lasso family) due together: one
        flush round serves both with one predict_batch each, the group
        whose head waited longest first — a hot group cannot starve the
        other."""
        served["service"].clear_cache()
        b, clock = self.mk(served, max_batch=8)
        calls = []
        real = served["service"].predict_batch

        def spy(graphs, setting=None, predictor=None):
            calls.append((predictor, len(graphs)))
            return real(graphs, setting, predictor)

        monkeypatch.setattr(served["service"], "predict_batch", spy)
        a = graphs_for(range(140, 143))
        c = graphs_for(range(143, 145))
        futs = [b.submit(g, SOURCE, "gbdt") for g in a]
        futs += [b.submit(g, SOURCE, "lasso") for g in c]
        clock.advance(2)
        assert b.run_pending() == 5
        # One call per group, gbdt first (its head arrived first).
        assert calls == [("gbdt", 3), ("lasso", 2)]
        want = [served["service"].predict_e2e(g, SOURCE, "gbdt").e2e_s
                for g in a]
        want += [served["service"].predict_e2e(g, SOURCE, "lasso").e2e_s
                 for g in c]
        assert [f.result(1).e2e_s for f in futs] == want

    def test_exactly_once_guard_is_loud(self, served):
        from repro.rpc.batcher import PendingResult
        p = PendingResult()
        p._resolve("x")
        with pytest.raises(RuntimeError):
            p._resolve("y")
        with pytest.raises(RuntimeError):
            p._fail(RPCError(protocol.E_INTERNAL, "again"))

    def test_unknown_setting_fails_typed(self, served):
        served["service"].clear_cache()
        b, clock = self.mk(served)
        fut = b.submit(graphs_for([150])[0],
                       DeviceSetting("other", "int8", "op_by_op"))
        b.flush_all()
        with pytest.raises(RPCError) as ei:
            fut.result(1)
        assert ei.value.code == protocol.E_UNKNOWN_SETTING
        assert b.stats()["failed"] == 1

    def test_no_default_setting_rejected_at_submit(self, served):
        svc = LatencyService(served["hub"], predictor="gbdt")
        b = MicroBatcher(svc, BatchPolicy(), clock=ManualClock(),
                         auto_start=False)
        with pytest.raises(RPCError) as ei:
            b.submit(graphs_for([151])[0])
        assert ei.value.code == protocol.E_UNKNOWN_SETTING

    def test_closed_batcher_rejects(self, served):
        b, clock = self.mk(served)
        b.close()
        with pytest.raises(RPCError) as ei:
            b.submit(graphs_for([152])[0])
        assert ei.value.code == protocol.E_UNAVAILABLE


# ---------------------------------------------------------------------------
# Server dispatch (no socket): handle_line sync mode
# ---------------------------------------------------------------------------

class TestDispatch:
    @pytest.fixture()
    def server(self, served):
        srv = LatencyRPCServer(served["service"],
                               policy=BatchPolicy(max_batch=4,
                                                  max_wait_ticks=1))
        yield srv
        srv.stop()

    def req(self, method, params, rid="t1"):
        return encode_request(Request(id=rid, method=method, params=params))

    def test_predict_matches_direct(self, served, server):
        g = sample_architecture(200, SPACE)
        out = server.handle_line(self.req("predict", {"graph": g.to_json()}))
        resp = decode_response(out)
        assert resp.ok
        rep = PredictionReport.from_json(resp.result["report"])
        assert rep.e2e_s == served["service"].predict_e2e(g).e2e_s
        assert rep.fingerprint == g.fingerprint()

    def test_unknown_method_envelope(self, server):
        resp = decode_response(server.handle_line(self.req("predictt", {})))
        assert not resp.ok and resp.error.code == protocol.E_UNKNOWN_METHOD
        assert not resp.error.retryable

    def test_malformed_line_still_answers(self, server):
        resp = decode_response(server.handle_line('{"broken'))
        assert not resp.ok and resp.error.code == protocol.E_BAD_REQUEST
        resp = decode_response(
            server.handle_line(json.dumps({"v": 5, "id": "z",
                                           "method": "stats"})))
        assert not resp.ok and resp.error.code == protocol.E_UNKNOWN_VERSION
        assert resp.id == "z"                 # id recovered best-effort

    def test_bad_graph_envelope(self, server):
        resp = decode_response(
            server.handle_line(self.req("predict", {"graph": {"name": "x"}})))
        assert not resp.ok and resp.error.code == protocol.E_BAD_GRAPH

    def test_predict_needs_graph(self, server):
        resp = decode_response(server.handle_line(self.req("predict", {})))
        assert not resp.ok and resp.error.code == protocol.E_BAD_REQUEST

    def test_internal_error_envelope(self, server, monkeypatch):
        """An unexpected handler crash leaves as a well-formed typed
        `internal` envelope — never a dead connection or raw traceback."""
        def boom(params):
            raise RuntimeError("predictor bank poisoned")
        monkeypatch.setattr(server, "_available", boom)
        resp = decode_response(server.handle_line(self.req("available", {})))
        assert not resp.ok
        assert resp.error.code == protocol.E_INTERNAL
        assert not resp.error.retryable
        assert "RuntimeError" in resp.error.message
        assert "predictor bank poisoned" in resp.error.message
        # The envelope re-encodes canonically (same invariant the golden
        # rpc_responses.jsonl internal line pins).
        line = encode_response(resp)
        assert encode_response(decode_response(line)) == line

    def test_health_endpoint(self, server):
        resp = decode_response(server.handle_line(self.req("health", {})))
        assert resp.ok
        h = resp.result
        assert h["status"] == "ok" and h["shed_tier"] == "accept"
        assert h["queued"] == 0
        assert h["queue_capacity"] == server.batcher.policy.max_queue
        assert h["hub_epoch"] >= 2            # the fixture trained 2 banks
        assert h["bank_epochs"]["float32/op_by_op"]["gbdt"] >= 1
        assert h["protocol_version"] == PROTOCOL_VERSION

    def test_rollover_bad_payloads_typed(self, server):
        resp = decode_response(server.handle_line(self.req("rollover", {})))
        assert not resp.ok and resp.error.code == protocol.E_BAD_REQUEST
        resp = decode_response(server.handle_line(self.req(
            "rollover", {"setting": "float32/op_by_op",
                         "bank": {"not": "a bank"}})))
        assert not resp.ok and resp.error.code == protocol.E_BAD_REQUEST

    def test_available_and_stats(self, served, server):
        resp = decode_response(server.handle_line(self.req("available", {})))
        assert ["float32/op_by_op", "gbdt"] in resp.result["banks"]
        resp = decode_response(server.handle_line(self.req("stats", {})))
        assert set(resp.result) == {"server", "batcher", "service"}
        assert resp.result["server"]["protocol_version"] == PROTOCOL_VERSION
        assert resp.result["batcher"]["policy"]["max_batch"] == 4

    def test_stream_transport_pipelined(self, served, server):
        import io
        gs = graphs_for(range(210, 216))
        lines = [self.req("predict", {"graph": g.to_json()}, rid=f"s{i}")
                 for i, g in enumerate(gs)]
        rfile = io.StringIO("".join(l + "\n" for l in lines) + "\n")
        wfile = io.StringIO()
        server.serve_stream(rfile, wfile)
        deadline = __import__("time").monotonic() + 10
        while (len([l for l in wfile.getvalue().splitlines() if l])
               < len(gs)) and __import__("time").monotonic() < deadline:
            __import__("time").sleep(0.01)
        out = {}
        for line in wfile.getvalue().splitlines():
            resp = decode_response(line)
            assert resp.ok
            out[resp.id] = PredictionReport.from_json(resp.result["report"])
        assert set(out) == {f"s{i}" for i in range(len(gs))}
        for i, g in enumerate(gs):
            assert out[f"s{i}"].fingerprint == g.fingerprint()
            assert out[f"s{i}"].e2e_s == served["service"].predict_e2e(g).e2e_s


# ---------------------------------------------------------------------------
# Socket server + pipelined client, end to end
# ---------------------------------------------------------------------------

class TestSocket:
    def test_predict_bit_identical_and_cached(self, live):
        g = sample_architecture(300, SPACE)
        direct = live["service"].predict_e2e(g)
        rep = live["client"].predict_e2e(g)
        assert rep.e2e_s == direct.e2e_s and rep.per_op == direct.per_op
        again = live["client"].predict_e2e(g)
        assert again.from_cache and again.e2e_s == direct.e2e_s

    def test_pipelined_coalesce_bit_identical(self, live):
        live["service"].clear_cache()
        gs = graphs_for(range(310, 326))
        before = live["server"].batcher.stats()
        reports = live["client"].predict_pipelined(gs, SOURCE)
        after = live["server"].batcher.stats()
        direct = [live["service"].predict_e2e(g) for g in gs]
        assert [r.e2e_s for r in reports] == [d.e2e_s for d in direct]
        assert [r.fingerprint for r in reports] == \
            [g.fingerprint() for g in gs]
        served_n = after["answered"] - before["answered"]
        new_batches = after["batches"] - before["batches"]
        assert served_n == len(gs)
        assert new_batches < len(gs)          # coalescing actually happened
        assert after["max_batch_observed"] >= 2

    def test_predict_multi_over_wire(self, live):
        gs = graphs_for(range(330, 333))
        multi = live["client"].predict_multi(gs, [SOURCE])
        direct = live["service"].predict_multi(gs, [SOURCE])
        assert set(multi) == set(direct) == {"float32/op_by_op"}
        assert [r.e2e_s for r in multi["float32/op_by_op"]] == \
            [r.e2e_s for r in direct["float32/op_by_op"]]

    def test_error_envelopes_over_wire(self, live):
        with pytest.raises(RPCError) as ei:
            live["client"].call("no_such_method", {})
        assert ei.value.code == protocol.E_UNKNOWN_METHOD
        with pytest.raises(RPCError) as ei:
            live["client"].predict_e2e(
                graphs_for([340])[0],
                DeviceSetting("other", "int8", "op_by_op"))
        assert ei.value.code == protocol.E_UNKNOWN_SETTING

    def test_server_drop_fails_fast(self, served):
        """After the server goes away, the client refuses new sends
        immediately instead of hanging to the full timeout."""
        server = LatencyRPCServer(served["service"])
        host, port = server.start()
        cli = LatencyClient(host, port, timeout=30.0)
        assert cli.available()                 # connection works
        server.stop()
        deadline = __import__("time").monotonic() + 5
        while __import__("time").monotonic() < deadline:
            try:
                cli.call("available", {}, timeout=0.2)
            except RPCError as exc:
                if exc.code == protocol.E_UNAVAILABLE:
                    break                      # reader noticed the close
            __import__("time").sleep(0.01)
        t0 = __import__("time").monotonic()
        with pytest.raises(RPCError) as ei:
            cli.call("available", {})
        assert ei.value.code == protocol.E_UNAVAILABLE
        assert __import__("time").monotonic() - t0 < 1.0   # no 30 s hang
        cli.close()

    def test_connection_loss_is_retryable_not_fatal(self, served):
        """Regression: a read-loop failure used to brick the client for
        good (every later send failed on the closed flag).  Now a lost
        connection fails in-flight work with a *retryable* envelope and
        later sends attempt a reconnect — the client object survives."""
        server = LatencyRPCServer(served["service"])
        host, port = server.start()
        cli = LatencyClient(host, port, timeout=30.0)
        assert cli.available()
        server.stop()
        # Every post-drop call fails retryable-unavailable (reconnects
        # refused — nothing listens) — never the terminal closed error.
        for _ in range(3):
            with pytest.raises(RPCError) as ei:
                cli.call("available", {}, timeout=0.5)
            assert ei.value.code == protocol.E_UNAVAILABLE
            assert ei.value.retryable, "lost connection must be retryable"
        # A server coming back on the SAME port heals the client.
        server2 = LatencyRPCServer(served["service"], host=host, port=port)
        server2.start()
        try:
            deadline = __import__("time").monotonic() + 5
            banks = None
            while __import__("time").monotonic() < deadline:
                try:
                    banks = cli.available()
                    break
                except RPCError:
                    __import__("time").sleep(0.05)
            assert banks, "client never recovered after server restart"
            assert cli.reconnects >= 1
        finally:
            cli.close()
            server2.stop()
        # After an explicit close the error is terminal, not retryable.
        with pytest.raises(RPCError) as ei:
            cli.call("available", {})
        assert ei.value.code == protocol.E_UNAVAILABLE
        assert not ei.value.retryable

    def test_overload_rejected_then_drains(self, served):
        server = LatencyRPCServer(
            served["service"],
            policy=BatchPolicy(max_batch=8, max_wait_ticks=10_000,
                               max_queue=2),
            clock=ManualClock(), auto_start_batcher=False)
        host, port = server.start()
        served["service"].clear_cache()
        with LatencyClient(host, port, timeout=30.0) as cli:
            gs = graphs_for(range(350, 353))
            slots = [cli.send("predict", {"graph": g.to_json()}) for g in gs]
            with pytest.raises(RPCError) as ei:
                cli.wait(slots[2], timeout=10)
            assert ei.value.code == protocol.E_OVERLOADED
            assert ei.value.retryable
            assert server.batcher.flush_all() == 2
            for s, g in zip(slots[:2], gs[:2]):
                rep = PredictionReport.from_json(
                    cli.wait(s, timeout=10)["report"])
                assert rep.fingerprint == g.fingerprint()
        server.stop()


# ---------------------------------------------------------------------------
# Search-front endpoint + ServeEngine over the wire
# ---------------------------------------------------------------------------

class TestSearchFront:
    @pytest.fixture(scope="class")
    def report(self, served):
        cfg = SearchConfig(population_size=12, generations=3,
                           children_per_gen=10, tournament_size=4, seed=11,
                           resolution=16, front_capacity=8)
        budgets = [DeviceBudget(SOURCE, served["budget_s"])]
        return SearchEngine(served["service"], budgets, cfg).run()

    def test_report_json_roundtrip(self, report):
        clone = SearchReport.from_json(json.loads(json.dumps(report.to_json())))
        assert clone.front_json() == report.front_json()
        assert clone.candidates_scored == report.candidates_scored

    def test_front_served_and_filtered(self, live, report):
        live["server"].register_search_report(report)
        out = live["client"].search_front()
        assert out["setting"] == "float32/op_by_op"
        assert out["total"] == len(report.front)
        qualities = [m["quality"] for m in out["members"]]
        assert qualities == sorted(qualities, reverse=True)
        # Budget filter keeps only members under the tighter budget.
        lats = sorted(m.latencies["float32/op_by_op"] for m in report.front)
        tight = lats[len(lats) // 2]
        out = live["client"].search_front(budget_s=tight)
        assert all(m["latencies"]["float32/op_by_op"] <= tight
                   for m in out["members"])
        assert 0 < out["total"] <= len(report.front)
        out = live["client"].search_front(limit=1)
        assert len(out["members"]) == 1 and out["total"] == len(report.front)

    def test_front_from_checkpoint_file(self, served, report, tmp_path,
                                        live):
        cfg = SearchConfig(population_size=12, generations=2,
                           children_per_gen=10, seed=5, resolution=16)
        budgets = [DeviceBudget(SOURCE, served["budget_s"])]
        eng = SearchEngine(served["service"], budgets, cfg)
        eng.step()
        path = str(tmp_path / "ckpt.json")
        eng.save(path)
        srv = live["server"]
        old = srv._front
        try:
            srv.register_search_report(path)
            out = live["client"].search_front()
            assert out["total"] == len(eng.front)
            assert all(set(m) >= {"digest", "genotype", "quality",
                                  "latencies"} for m in out["members"])
        finally:
            srv._front = old

    def test_unknown_setting_and_unregistered(self, served, live, report):
        live["server"].register_search_report(report)
        with pytest.raises(RPCError) as ei:
            live["client"].search_front(setting="int8/op_by_op")
        assert ei.value.code == protocol.E_UNKNOWN_SETTING
        srv = LatencyRPCServer(served["service"])
        try:
            resp = decode_response(srv.handle_line(encode_request(
                Request(id="q", method="search_front", params={}))))
            assert not resp.ok
            assert resp.error.code == protocol.E_UNAVAILABLE
        finally:
            srv.stop()


class _StubModel:
    """Minimal decode-capable model (mirrors tests/test_pipeline.py)."""

    def init_cache(self, slots, max_len):
        return {"pos": 0}

    def decode_step(self, params, batch, cache):
        import jax.numpy as jnp
        logits = jnp.tile(jnp.arange(8.0), (batch["token"].shape[0], 1))
        return logits, {"pos": cache["pos"] + 1}


class TestServeEngineOverRPC:
    def test_decode_step_estimate_via_client(self, live):
        from repro.serving import ServeEngine
        step = sample_architecture(400, SPACE)
        direct = live["service"].predict_e2e(step, SOURCE)
        eng = ServeEngine(_StubModel(), params={}, batch_slots=2, max_len=16,
                          latency_service=live["client"], step_graph=step,
                          latency_setting=SOURCE)
        assert eng.predicted_step_s == direct.e2e_s
        assert eng.stats()["prediction_source"] == "LatencyClient"
        assert eng.estimate_request_s(4, 8) == pytest.approx(
            direct.e2e_s * 11)

    def test_wire_dict_report_normalized(self, served):
        from repro.serving import ServeEngine

        class DictService:
            def predict_e2e(self, graph, setting=None):
                return served["service"].predict_e2e(graph, setting).to_json()

        step = sample_architecture(401, SPACE)
        eng = ServeEngine(_StubModel(), params={}, batch_slots=2, max_len=16,
                          latency_service=DictService(), step_graph=step,
                          latency_setting=SOURCE)
        assert eng.predicted_step_s == \
            served["service"].predict_e2e(step, SOURCE).e2e_s
        assert eng.step_report.num_kernels > 0

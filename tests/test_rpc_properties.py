"""Property + state-machine tests for the micro-batcher's flush policy.

The hypothesis-driven half explores *arbitrary* arrival orders,
batch-size/wait policies, and tick sequences — driven synchronously
under a `ManualClock` with no worker thread, so the schedule is pure
state-machine — and checks the batcher:

  * answers every request exactly once (none lost, double-resolution
    raises);
  * never cross-wires: each answer is the per-request value the backing
    service computes for exactly that request's graph, bit-identical
    to calling it directly;
  * respects the policy: no flushed batch exceeds ``max_batch``; within
    a (setting, family) group, requests are served FIFO;
  * is deterministic: replaying the same event script yields the exact
    same flush sequence (same batches, same composition, same order).

hypothesis is an optional dev dependency (requirements-dev.txt): when
absent, the property half is skipped but the deterministic edge-case
half below — `PendingResult` timeout semantics, `ManualClock` deadline
boundaries — still runs everywhere.

The backing service is a stub (the batcher only needs
``cache_peek``/``predict_batch``/``default_setting``/``predictor``), so
thousands of drawn cases run in milliseconds; bit-identity against the
*real* `LatencyService` is covered deterministically in
tests/test_rpc.py and tests/test_concurrency.py.
"""
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                       # optional dep — property half skips
    HAS_HYPOTHESIS = False

from repro.core.profiler import DeviceSetting
from repro.rpc.batcher import (BatchPolicy, ManualClock, MicroBatcher,
                               PendingResult)
from repro.rpc.protocol import E_TIMEOUT, RPCError

SETTINGS = (DeviceSetting("dev_a", "float32", "op_by_op"),
            DeviceSetting("dev_b", "int8", "op_by_op"))


class FakeGraph:
    """The batcher never inspects graphs — an opaque token suffices."""

    __slots__ = ("uid",)

    def __init__(self, uid):
        self.uid = uid


class StubService:
    """Deterministic predict_batch that records every call's composition."""

    def __init__(self, cached_uids=frozenset()):
        self.default_setting = SETTINGS[0]
        self.predictor = "gbdt"
        self.calls = []
        self.cached_uids = set(cached_uids)

    @staticmethod
    def value_of(uid, setting, family):
        return float(hash((uid, setting.dtype, family)) % 100003)

    def cache_peek(self, graph, setting, family):
        if graph.uid in self.cached_uids:
            return ("cached", graph.uid,
                    self.value_of(graph.uid, setting, family))
        return None

    def predict_batch(self, graphs, setting, family):
        self.calls.append((setting.dtype, family,
                           tuple(g.uid for g in graphs)))
        return [("fresh", g.uid, self.value_of(g.uid, setting, family))
                for g in graphs]


def drive(events, policy, cached_uids=frozenset()):
    """Run one script; returns (service, futures, uid sequence per sub)."""
    svc = StubService(cached_uids)
    clock = ManualClock()
    b = MicroBatcher(svc, policy, clock=clock, auto_start=False)
    futures = []
    uid_seq = 0
    for kind, a, c in events:
        if kind == "submit":
            g = FakeGraph((a, c, uid_seq))    # unique per submission
            uid_seq += 1
            futures.append((g, SETTINGS[a], b.submit(g, SETTINGS[a])))
            b.run_pending()                    # size-triggered flushes
        elif kind == "advance":
            clock.advance(a)
            b.run_pending()                    # deadline-triggered flushes
        else:
            b.run_pending()
    b.flush_all()
    return svc, futures, b


# ---------------------------------------------------------------------------
# Deterministic edge cases (no hypothesis needed)
# ---------------------------------------------------------------------------

class TestPendingResultTimeout:
    def test_unsettled_result_raises_retryable_timeout(self):
        p = PendingResult()
        with pytest.raises(RPCError) as ei:
            p.result(timeout=0.02)
        assert ei.value.code == E_TIMEOUT
        assert ei.value.retryable          # callers may safely re-poll
        assert "0.02" in ei.value.message

    def test_timeout_does_not_settle_the_future(self):
        """A timed-out wait is an observer giving up, not a resolution:
        the future stays open and settles exactly once later."""
        p = PendingResult()
        with pytest.raises(RPCError):
            p.result(timeout=0)
        assert not p.done()
        p._resolve("late answer")
        assert p.done()
        assert p.result(0) == "late answer"
        with pytest.raises(RuntimeError):   # exactly-once still enforced
            p._resolve("again")

    def test_zero_timeout_polls_immediately(self):
        p = PendingResult()
        t0 = time.monotonic()
        with pytest.raises(RPCError) as ei:
            p.result(timeout=0)
        assert ei.value.code == E_TIMEOUT
        assert time.monotonic() - t0 < 1.0  # a poll, not a wait

    def test_settled_future_ignores_timeout(self):
        p = PendingResult()
        p._resolve("x")
        assert p.result(timeout=0) == "x"


class TestManualClockDeadlineEdges:
    def mk(self, **kw):
        svc = StubService()
        clock = ManualClock()
        policy = BatchPolicy(**{"max_batch": 8, "max_wait_ticks": 2,
                                "max_queue": 64, **kw})
        return svc, clock, MicroBatcher(svc, policy, clock=clock,
                                        auto_start=False)

    def test_zero_max_wait_ticks_due_immediately(self):
        """max_wait_ticks=0: the deadline IS the submit tick, so the
        request is due with no advance at all."""
        svc, clock, b = self.mk(max_wait_ticks=0)
        fut = b.submit(FakeGraph("t0"))
        assert b.run_pending() == 1
        assert fut.done() and fut.result(0)[1] == "t0"
        assert svc.calls == [("float32", "gbdt", ("t0",))]

    def test_deadline_exactly_at_now_is_due(self):
        """Boundary semantics are ``deadline <= now``: one tick short of
        the deadline nothing flushes; landing exactly on it flushes."""
        svc, clock, b = self.mk(max_wait_ticks=2)
        fut = b.submit(FakeGraph("edge"))
        assert b.run_pending() == 0         # t=0: not due
        clock.advance(1)
        assert b.run_pending() == 0         # t=1: one tick early, not due
        clock.advance(1)                    # t=2 == deadline exactly
        assert b.run_pending() == 1
        assert fut.done()

    def test_overshoot_past_deadline_still_served_once(self):
        svc, clock, b = self.mk(max_wait_ticks=1)
        fut = b.submit(FakeGraph("late"))
        clock.advance(10)                   # far past the deadline
        assert b.run_pending() == 1
        assert fut.result(0)[1] == "late"
        assert b.run_pending() == 0         # nothing left, nothing doubled
        assert b.stats()["answered"] == 1

    def test_advance_wakes_subscribers(self):
        clock = ManualClock()
        hits = []
        clock.subscribe(lambda: hits.append(clock.now()))
        assert clock.advance(3) == 3
        assert clock.advance(2) == 5
        assert hits == [3, 5]


# ---------------------------------------------------------------------------
# Hypothesis property half (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    # Event scripts: submit (which setting, which token) / advance / pump.
    EVENTS = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, 1),
                      st.integers(0, 30)),
            st.tuples(st.just("advance"), st.integers(1, 4), st.just(0)),
            st.tuples(st.just("pump"), st.just(0), st.just(0)),
        ),
        min_size=1, max_size=40)

    POLICIES = st.builds(
        BatchPolicy,
        max_batch=st.integers(1, 6),
        max_wait_ticks=st.integers(0, 4),
        max_queue=st.just(10_000))

    @settings(max_examples=120, deadline=None)
    @given(events=EVENTS, policy=POLICIES)
    def test_every_request_answered_exactly_once(events, policy):
        svc, futures, b = drive(events, policy)
        submits = [e for e in events if e[0] == "submit"]
        assert len(futures) == len(submits)
        for g, setting, fut in futures:
            assert fut.done()                      # nothing lost
            kind, uid, value = fut.result(0)
            assert uid == g.uid                    # not cross-wired
            assert value == StubService.value_of(g.uid, setting, "gbdt")
        st_ = b.stats()
        assert st_["answered"] == len(futures)
        assert st_["failed"] == st_["rejected"] == 0
        assert st_["queued"] == 0
        # Every non-short-circuited request appears in exactly one call.
        flushed = [uid for _, _, uids in svc.calls for uid in uids]
        assert len(flushed) == len(set(flushed)) == \
            len(futures) - st_["short_circuits"]

    @settings(max_examples=120, deadline=None)
    @given(events=EVENTS, policy=POLICIES)
    def test_batches_bounded_and_fifo_per_group(events, policy):
        svc, futures, _ = drive(events, policy)
        per_group_served = {}
        for dtype, family, uids in svc.calls:
            assert 1 <= len(uids) <= policy.max_batch
            per_group_served.setdefault(dtype, []).extend(uids)
        per_group_submitted = {}
        for g, setting, _fut in futures:
            per_group_submitted.setdefault(setting.dtype, []).append(g.uid)
        assert per_group_served == per_group_submitted   # FIFO, group-local

    @settings(max_examples=80, deadline=None)
    @given(events=EVENTS, policy=POLICIES)
    def test_flush_schedule_deterministic_on_replay(events, policy):
        svc1, _, _ = drive(events, policy)
        svc2, _, _ = drive(events, policy)
        assert svc1.calls == svc2.calls

    @settings(max_examples=80, deadline=None)
    @given(events=EVENTS, policy=POLICIES,
           cached=st.sets(st.integers(0, 30), max_size=10))
    def test_cache_short_circuits_never_enqueue(events, policy, cached):
        # Mark some *tokens* cached: any submission whose token id is in
        # the set answers immediately from cache_peek and must not reach
        # predict_batch.
        svc = StubService()
        clock = ManualClock()
        b = MicroBatcher(svc, policy, clock=clock, auto_start=False)
        futures = []
        for i, (kind, a, c) in enumerate(events):
            if kind == "submit":
                g = FakeGraph((a, c, i))
                if c in cached:
                    svc.cached_uids.add(g.uid)
                futures.append((g, SETTINGS[a], c in cached,
                                b.submit(g, SETTINGS[a])))
                b.run_pending()
            elif kind == "advance":
                clock.advance(a)
                b.run_pending()
            else:
                b.run_pending()
        b.flush_all()
        flushed = {uid for _, _, uids in svc.calls for uid in uids}
        n_cached = 0
        for g, setting, was_cached, fut in futures:
            kind, uid, value = fut.result(0)
            assert uid == g.uid
            if was_cached:
                n_cached += 1
                assert kind == "cached" and g.uid not in flushed
            else:
                assert kind == "fresh"
        assert b.stats()["short_circuits"] == n_cached
else:
    def test_hypothesis_property_half_skipped():
        pytest.skip("hypothesis not installed — property half skipped "
                    "(deterministic edge cases above still ran)")

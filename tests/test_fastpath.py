"""Compiled prediction fast path (no optional deps — run everywhere).

Covers: FlatEnsemble structure + serialization rebuild, the jax gather
backend, `feature_names` lazy probe, `GraphFeatures` + its fingerprint
LRU, the bounded `ProfileSession.fn_cache`, and featurize-once
profiling.  Property-based flattened-vs-oracle parity lives in
tests/test_predictors.py behind the hypothesis guard.
"""
import json

import numpy as np
import pytest

from repro.core import features as features_mod
from repro.core.features import (
    GraphFeatures, clear_graph_feature_cache, feature_names, featurize,
    graph_feature_cache_info, graph_features,
)
from repro.core.ir import OpGraph
from repro.core.predictors import (
    FlatEnsemble, GBDTPredictor, RandomForestPredictor, load_predictor,
)
from repro.core.predictors.trees import RegressionTree
from repro.core.profiler import DeviceSetting, ProfileSession
from repro.pipeline import ProfileStore
from repro.utils.lru import LRUCache

SETTING = DeviceSetting("cpu_f32", "float32", "op_by_op")


def _data(n=200, d=8, seed=0):
    rng = np.random.default_rng(seed)
    x = np.abs(rng.standard_normal((n, d))) * np.linspace(1, 30, d)
    y = x @ rng.random(d) + 0.1
    return x, y


def tiny_graph(name="t", ch=4):
    g = OpGraph(name)
    x0 = g.add_input((1, 4, 4, ch))
    (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, ch)],
                     {"kernel_h": 3, "kernel_w": 3, "stride": 1, "groups": 1})
    (e1,) = g.add_op("elementwise", [c1], [(1, 4, 4, ch)], {"ew_kind": "add"})
    (m1,) = g.add_op("mean", [e1], [(1, ch)])
    g.mark_output(m1)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# FlatEnsemble structure + serialization
# ---------------------------------------------------------------------------

class TestFlatEnsemble:
    def test_structure_invariants(self):
        x, y = _data()
        m = GBDTPredictor(n_stages=10).fit(x, y)
        flat = m.flat()
        assert flat.n_trees == 10
        assert flat.n_nodes == sum(len(t.nodes) for t in m.trees)
        leaves = flat.feature < 0
        # Leaves self-loop; internal children stay in-bank and differ.
        idx = np.arange(flat.n_nodes)
        assert np.array_equal(flat.left[leaves], idx[leaves])
        assert np.array_equal(flat.right[leaves], idx[leaves])
        internal = ~leaves
        assert (flat.left[internal] != flat.right[internal]).all()
        assert flat.left.min() >= 0 and flat.right.max() < flat.n_nodes
        assert flat.max_depth >= 1

    def test_unfitted_raises(self):
        with pytest.raises(ValueError):
            FlatEnsemble.from_trees([])
        with pytest.raises(ValueError):
            FlatEnsemble.from_trees([RegressionTree()])

    @pytest.mark.parametrize("family,kw", [
        (RandomForestPredictor, {"n_trees": 6}),
        (GBDTPredictor, {"n_stages": 30}),
    ])
    def test_roundtrip_rebuilds_flat_arrays_bit_identically(self, family, kw):
        x, y = _data()
        m = family(**kw).fit(x, y)
        m2 = load_predictor(json.loads(json.dumps(m.to_json())))
        f1, f2 = m.flat(), m2.flat()
        for name in ("feature", "threshold", "left", "right", "value", "roots"):
            a, b = getattr(f1, name), getattr(f2, name)
            assert a.dtype == b.dtype and np.array_equal(a, b), name
        assert f1.max_depth == f2.max_depth
        assert np.array_equal(m.predict(x), m2.predict(x))

    def test_bank_load_is_warm(self):
        from repro.core.composition import PredictorBank

        x, y = _data()
        bank = PredictorBank(setting="cpu_f32")
        bank.predictors["conv2d"] = GBDTPredictor(n_stages=10).fit(x, y)
        bank2 = PredictorBank.from_json(json.loads(json.dumps(bank.to_json())))
        # from_json warms: flattened state exists before the first query.
        assert bank2.predictors["conv2d"]._flat is not None

    def test_jax_backend_matches_numpy(self):
        pytest.importorskip("jax")
        x, y = _data()
        m = GBDTPredictor(n_stages=40).fit(x, y)
        q, _ = _data(n=257, seed=1)
        flat = m.flat()
        xs = m.scaler.transform(q)
        ref = flat.predict_trees(xs, backend="numpy")
        got = flat.predict_trees(xs, backend="jax")
        assert got.shape == ref.shape
        # jax runs at its default precision (float32 unless x64): close,
        # not necessarily bit-equal.
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-7)

    def test_unknown_backend_raises(self):
        x, y = _data()
        t = RegressionTree(max_depth=3).fit(x, y)
        with pytest.raises(ValueError):
            t.flat().predict_trees(x, backend="cuda")


# ---------------------------------------------------------------------------
# feature_names lazy probe (satellite regression)
# ---------------------------------------------------------------------------

class TestFeatureNames:
    def test_names_without_prior_featurize(self):
        # Regression: indexing the name cache raised KeyError for any op
        # type whose featurizer had never run in this process.
        features_mod._NAME_CACHE.pop("ssd_scan", None)
        names = feature_names("ssd_scan")
        assert names == ["batch", "seq", "heads", "head_dim", "state", "flops"]

    def test_names_match_real_featurization(self):
        g = tiny_graph()
        features_mod._NAME_CACHE.pop("conv2d", None)
        probed = feature_names("conv2d")
        real_names, vec = featurize(g, g.nodes[0])
        assert probed == real_names and len(vec) == len(probed)

    def test_unknown_type_raises(self):
        with pytest.raises(KeyError):
            feature_names("not_an_op")

    def test_every_registered_type_probes(self):
        for op_type in features_mod._FEATURIZERS:
            assert len(feature_names(op_type)) > 0


# ---------------------------------------------------------------------------
# GraphFeatures + fingerprint LRU
# ---------------------------------------------------------------------------

class TestGraphFeatures:
    def test_matches_per_node_featurize(self):
        g = tiny_graph()
        gf = GraphFeatures.from_graph(g)
        assert gf.num_nodes == 3
        for k, node in enumerate(g.nodes):
            names, vec = featurize(g, node)
            assert gf.node_names(k) == names
            assert np.array_equal(gf.node_features(k), vec)
        assert sorted(gf.matrix) == ["conv2d", "elementwise", "mean"]
        for t, mat in gf.matrix.items():
            assert mat.shape[0] == len(gf.index[t])

    def test_type_grouping_row_order(self):
        g = OpGraph("two")
        x0 = g.add_input((1, 4, 4, 2))
        (e1,) = g.add_op("elementwise", [x0], [(1, 4, 4, 2)], {"ew_kind": "add"})
        (e2,) = g.add_op("elementwise", [e1], [(1, 4, 4, 2)], {"ew_kind": "mul"})
        g.mark_output(e2)
        gf = GraphFeatures.from_graph(g)
        assert list(gf.index["elementwise"]) == [0, 1]
        assert gf.slots == [("elementwise", 0), ("elementwise", 1)]
        assert np.array_equal(gf.matrix["elementwise"][1],
                              featurize(g, g.nodes[1])[1])

    def test_cache_hit_returns_same_object(self):
        clear_graph_feature_cache()
        g = tiny_graph()
        gf1 = graph_features(g)
        gf2 = graph_features(g)
        assert gf1 is gf2
        assert graph_feature_cache_info()["size"] == 1
        # Structurally identical graph → same fingerprint → same entry.
        assert graph_features(tiny_graph()) is gf1

    def test_cache_bounded(self):
        clear_graph_feature_cache()
        cap = graph_feature_cache_info()["probation_capacity"]
        # Unpinned inserts (one-shot candidates) only cycle probation.
        for i in range(cap + 5):
            graph_features(tiny_graph(ch=i + 1))
        info = graph_feature_cache_info()
        assert info["probation"] == cap
        assert info["protected"] == 0
        assert info["size"] <= info["capacity"]

    def test_pinned_graphs_survive_one_shot_scan(self, monkeypatch):
        # Search-workload thrash regression: scoring thousands of
        # one-shot candidate fingerprints must not evict the pinned
        # (profiled/training) graphs' entries.
        clear_graph_feature_cache()
        train = tiny_graph(ch=3)
        gf = graph_features(train, pin=True)
        cap = graph_feature_cache_info()["probation_capacity"]
        for i in range(cap + 50):                 # a full probation cycle
            graph_features(tiny_graph(ch=i + 10))
        calls = {"n": 0}
        real = features_mod.featurize

        def counting(graph, node):
            calls["n"] += 1
            return real(graph, node)

        monkeypatch.setattr(features_mod, "featurize", counting)
        assert graph_features(train) is gf        # served from protected
        assert calls["n"] == 0
        info = graph_feature_cache_info()
        assert info["protected"] == 1
        assert info["probation"] == info["probation_capacity"]


# ---------------------------------------------------------------------------
# Bounded fn_cache + featurize-once profiling (satellites)
# ---------------------------------------------------------------------------

class TestLRUCache:
    def test_eviction_order(self):
        c = LRUCache(maxsize=2)
        c["a"], c["b"] = 1, 2
        assert c.get("a") == 1          # refresh a → b is now LRU
        c["c"] = 3
        assert "b" not in c and "a" in c and "c" in c

    def test_getitem_refreshes(self):
        c = LRUCache(maxsize=2)
        c["a"], c["b"] = 1, 2
        _ = c["a"]
        c["c"] = 3
        assert list(c) == ["a", "c"]


class TestProfileSessionFastPath:
    def fast_session(self, **kw):
        return ProfileSession(warmup=0, inner=1, repeats=1,
                              e2e_inner=1, e2e_repeats=1, **kw)

    def test_fn_cache_bounded_and_in_stats(self):
        s = self.fast_session(fn_cache_size=2)
        # Capacity grows to cover the largest single graph (eviction
        # mid-profile would re-jit ops the executor just compiled) …
        s.profile_graph(tiny_graph(), SETTING)   # 3 distinct op signatures
        stats = s.stats()
        assert stats["fn_cache_capacity"] == 3
        assert stats["fn_cache_size"] <= 3
        # … but stays bounded across a suite: 5 graphs × 3 distinct
        # signatures compile 15 fns, the cache never exceeds 3.
        for ch in (6, 8, 10, 12):       # differs from the first graph's ch=4
            s.profile_graph(tiny_graph(ch=ch), SETTING)
        stats = s.stats()
        assert stats["fn_cache_capacity"] == 3
        assert stats["fn_cache_size"] <= 3
        assert stats["measured_ops"] == 15
        assert stats["latency_cache_size"] == 15  # latencies stay unbounded

    def test_featurize_once_per_node(self, monkeypatch, tmp_path):
        clear_graph_feature_cache()
        calls = {"n": 0}
        real = features_mod.featurize

        def counting(graph, node):
            calls["n"] += 1
            return real(graph, node)

        monkeypatch.setattr(features_mod, "featurize", counting)
        store = ProfileStore(str(tmp_path / "s.jsonl"))
        s = self.fast_session(store=store)
        s.profile_graph(tiny_graph(), SETTING)
        # One featurization per node (store write reuses it); the old
        # path ran measure_op's + profile_graph's featurize separately.
        assert calls["n"] == 3

    def test_store_features_match_direct(self, tmp_path):
        store = ProfileStore(str(tmp_path / "s.jsonl"))
        s = self.fast_session(store=store)
        g = tiny_graph()
        s.profile_graph(g, SETTING)
        rec = store.arch_records(SETTING)[0]
        for op, node in zip(rec.ops, g.nodes):
            names, vec = featurize(g, node)
            assert op.feature_names == names
            assert op.features == [float(v) for v in vec]


# ---------------------------------------------------------------------------
# Device residency + three-tier backend (PR 6)
# ---------------------------------------------------------------------------

class TestDeviceResidency:
    def test_bank_uploaded_once_across_flushes(self):
        # Satellite regression: predict_trees_jax used to rebuild its
        # device arrays per ensemble lazily but re-upload x every call
        # with nothing pinning the bank's lifecycle; now the bank rides
        # a DeviceBank that survives across flushes.
        pytest.importorskip("jax")
        x, y = _data()
        m = GBDTPredictor(n_stages=10).fit(x, y)
        flat = m.flat()
        xs = m.scaler.transform(x)
        flat.predict_trees(xs, backend="jax")
        db = flat._device_bank
        assert db is not None and db.uploads == 1
        for _ in range(3):
            flat.predict_trees(xs, backend="jax")
        # No per-call host→device transfer of bank arrays: same bank
        # object, upload count pinned at one; only inputs are staged.
        assert flat._device_bank is db
        assert db.uploads == 1
        assert db.inputs_staged == 4

    def test_invalidated_on_refit(self):
        pytest.importorskip("jax")
        x, y = _data()
        m = GBDTPredictor(n_stages=5).fit(x, y)
        m.flat().predict_trees(m.scaler.transform(x), backend="jax")
        old = m.flat()._device_bank
        assert old is not None
        m.fit(x, y + 1.0)                 # retrain → flat (and bank) drop
        assert m._flat is None and m._device_scaler is None
        m.flat().predict_trees(m.scaler.transform(x), backend="jax")
        assert m.flat()._device_bank is not old

    def test_predict_on_device_matches_host_predict(self):
        pytest.importorskip("jax")
        x, y = _data()
        for m in (GBDTPredictor(n_stages=20).fit(x, y),
                  RandomForestPredictor(n_trees=6).fit(x, y)):
            host = m.predict(x)
            dev = m.predict_on_device(np.asarray(x, np.float32))
            np.testing.assert_allclose(dev, host, rtol=1e-3, atol=1e-5)
            assert (dev >= 0).all()

    def test_device_stats_lazy(self):
        pytest.importorskip("jax")
        x, y = _data()
        m = GBDTPredictor(n_stages=5).fit(x, y)
        assert m.device_stats() is None      # nothing resident yet
        m.flat().predict_trees(m.scaler.transform(x), backend="jax")
        st = m.device_stats()
        assert st is not None and st["uploads"] == 1
        assert st["n_trees"] == 5 and st["nbytes"] > 0


class TestBackendTiers:
    def test_resolve_three_tiers(self, monkeypatch):
        pytest.importorskip("jax")
        from repro.core.predictors import flat as flat_mod

        monkeypatch.setattr(flat_mod, "_pallas_available", lambda: True)
        assert flat_mod.resolve_backend("auto", 100) == "numpy"
        assert flat_mod.resolve_backend(
            "auto", flat_mod.AUTO_JAX_MIN_SLOTS) == "jax"
        assert flat_mod.resolve_backend(
            "auto", flat_mod.AUTO_PALLAS_MIN_SLOTS) == "pallas"
        # Explicit backends pass through untouched.
        for b in ("numpy", "jax", "pallas"):
            assert flat_mod.resolve_backend(b, 1) == b

    def test_pallas_tier_needs_compiled_backend(self, monkeypatch):
        pytest.importorskip("jax")
        from repro.core.predictors import flat as flat_mod

        monkeypatch.setattr(flat_mod, "_pallas_available", lambda: False)
        # Without a compiled Pallas backend the top tier degrades to jax
        # rather than serving through interpret mode.
        assert flat_mod.resolve_backend(
            "auto", flat_mod.AUTO_PALLAS_MIN_SLOTS) == "jax"

    def test_pallas_available_env_override(self, monkeypatch):
        pytest.importorskip("jax")
        from repro.core.predictors.flat import _pallas_available

        monkeypatch.delenv("REPRO_AUTO_PALLAS", raising=False)
        assert _pallas_available() is False     # CPU container: no TPU
        monkeypatch.setenv("REPRO_AUTO_PALLAS", "1")
        assert _pallas_available() is True

    def test_auto_resolving_to_numpy_is_bit_exact(self):
        # "auto" must never silently change reports when it resolves to
        # numpy: small-batch auto == explicit numpy, bit for bit.
        from repro.pipeline import LatencyService

        graphs = [tiny_graph(f"g{i}", ch=2 * i + 2) for i in range(6)]
        auto_svc = LatencyService.build(graphs, SETTING,
                                        predictor="gbdt")
        assert auto_svc.inference_backend == "auto"
        np_svc = LatencyService(auto_svc.hub, default_setting=SETTING,
                                predictor="gbdt", inference_backend="numpy")
        auto_reports = auto_svc.predict_batch(graphs, SETTING)
        np_reports = np_svc.predict_batch(graphs, SETTING)
        for a, b in zip(auto_reports, np_reports):
            assert a.e2e_s == b.e2e_s
            assert a.per_op == b.per_op
        runs = auto_svc.stats()["backend_runs"]
        assert runs.get("numpy", 0) > 0          # the tier actually ran
        assert runs.get("jax", 0) == 0
        assert runs.get("pallas", 0) == 0


class TestServiceDevicePath:
    def _service(self):
        from repro.pipeline import LatencyService

        graphs = [tiny_graph(f"g{i}", ch=2 * i + 2) for i in range(6)]
        svc = LatencyService.build(graphs, SETTING, predictor="gbdt")
        return svc, graphs

    def test_fused_device_flush(self, monkeypatch):
        pytest.importorskip("jax")
        from repro.core.predictors import flat as flat_mod

        svc, graphs = self._service()
        np_reports = svc.predict_batch(graphs, SETTING)
        svc.clear_cache()
        # Force the jax tier for any batch size: the flush must route
        # through the fused device path (tallied separately) and stay
        # close to the float64 host reports.
        monkeypatch.setattr(flat_mod, "AUTO_JAX_MIN_SLOTS", 1)
        dev_reports = svc.predict_batch(graphs, SETTING)
        stats = svc.stats()
        assert stats["backend_runs"].get("jax", 0) > 0
        assert stats["device_fused_runs"] > 0
        for a, b in zip(dev_reports, np_reports):
            np.testing.assert_allclose(a.e2e_s, b.e2e_s,
                                       rtol=1e-3, atol=1e-6)
        res = stats["device_residency"]
        assert res["banks"] > 0 and res["bytes"] > 0
        assert res["lifetime"]["banks_built"] >= res["banks"]

    def test_stats_report_residency_without_device_use(self):
        svc, graphs = self._service()
        svc.predict_batch(graphs, SETTING)       # numpy tier only
        res = svc.stats()["device_residency"]
        assert res["banks"] == 0 and res["bytes"] == 0

"""Cross-device transfer tests (tentpole: `repro.transfer`).

Covers the issue's required cases — calibration JSON round-trip
bit-exactness, sampler determinism under a fixed seed, and the
budget-curve smoke test (e2e MAPE at K=64 ≤ MAPE at K=8 on the
synthetic device pair, within 2× of the fully-profiled oracle, under
budget) — plus the satellite behaviors (`ProfileStore.compact`,
`PredictorHub.load` hardening, device-tagged setting keys).

The source device is a `CostModelProfileSession` (deterministic
feature-derived latencies), so every asserted number is identical
across runs; one test exercises the real wall-clock path.
"""
import json
import os

import numpy as np
import pytest

from repro.core.composition import PredictorBank, mape
from repro.core.dataset import synthetic_graphs
from repro.core.ir import OpGraph
from repro.core.predictors import load_predictor, make_predictor
from repro.core.profiler import DeviceSetting, ProfileSession
from repro.core.selection import get_device
from repro.pipeline import (LatencyService, PredictorHub, ProfileStore,
                            op_axis, setting_key)
from repro.transfer import (DESCRIPTOR_FIELDS, CostModelProfileSession,
                            DeviceDescriptor, LatencyMap,
                            ReplayProfileSession, SyntheticDevice,
                            TransferEngine, describe, descriptor_distance,
                            fit_latency_map, plan_samples, prior_scale,
                            scale_map)

SRC = DeviceSetting("cpu_f32", "float32", "op_by_op")
TGT = DeviceSetting("sim_f32", "float32", "op_by_op", device="simdev")


def tiny_graph(name="t", ch=4):
    g = OpGraph(name)
    x0 = g.add_input((1, 4, 4, ch))
    (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, ch)],
                     {"kernel_h": 3, "kernel_w": 3, "stride": 1, "groups": 1})
    (e1,) = g.add_op("elementwise", [c1], [(1, 4, 4, ch)], {"ew_kind": "add"})
    (m1,) = g.add_op("mean", [e1], [(1, ch)])
    g.mark_output(m1)
    g.validate()
    return g


@pytest.fixture(scope="module")
def source():
    """Deterministic fully-profiled source: (store, graphs, hub, bank)."""
    graphs = synthetic_graphs(12, resolution=16)
    store = ProfileStore()
    sess = CostModelProfileSession(store=store, seed=1)
    for g in graphs:
        sess.profile_graph(g, SRC)
    hub = PredictorHub()
    train_fps = [g.fingerprint() for g in graphs[:9]]
    bank = hub.train(store, SRC, "gbdt", hparams={"n_stages": 50},
                     min_samples=3, fingerprints=train_fps)
    return store, graphs, hub, bank


# ---------------------------------------------------------------------------
# Device identity: setting keys + descriptors
# ---------------------------------------------------------------------------

class TestDeviceIdentity:
    def test_device_tag_in_keys(self):
        assert setting_key(SRC) == "float32/op_by_op"      # unchanged
        assert setting_key(TGT) == "simdev:float32/op_by_op"
        assert op_axis(SRC) == "float32"
        assert op_axis(TGT) == "simdev:float32"

    def test_device_tag_delimiters_rejected(self):
        # '/', ':' and '__' delimit setting keys and bank filenames; a
        # tag containing them would corrupt the hub save/load round-trip.
        for bad in ("pixel/4", "pixel__4", "pixel:4"):
            with pytest.raises(ValueError):
                DeviceSetting("x", "float32", "op_by_op", device=bad)
        DeviceSetting("x", "float32", "op_by_op", device="pixel_4a.rev-b")

    def test_descriptor_shape_and_roundtrip(self):
        d = describe(get_device("cpu_xla"), SRC)
        assert len(d.values) == len(DESCRIPTOR_FIELDS)
        d2 = DeviceDescriptor.from_json(json.loads(json.dumps(d.to_json())))
        assert d2 == d
        assert descriptor_distance(d, d2) == 0.0

    def test_distance_symmetric(self):
        a = describe(get_device("cpu_xla"), SRC)
        b = describe(get_device("tpu_v5e"), SRC)
        assert descriptor_distance(a, b) == descriptor_distance(b, a) > 0

    def test_prior_scale_from_flops(self):
        a = describe(get_device("cpu_xla"))       # 50 GFLOP/s
        b = describe(get_device("tpu_v5e"))       # 197 TFLOP/s
        # Target is much faster → expected latency ratio < 1.
        assert prior_scale(a, b) == pytest.approx(50e9 / 197e12)
        assert prior_scale(b, a) == pytest.approx(197e12 / 50e9)
        assert prior_scale(None, a) == 1.0

    def test_prior_scale_cores_clock_fallback(self):
        from repro.core.selection import DeviceProfile
        # No FLOP rates reported; a real 1.0 GHz clock (log == 0, same
        # encoding as "unknown") must still contribute to the ratio.
        src = describe(DeviceProfile("big", "cpu", cores=8, freq_ghz=2.0))
        tgt = describe(DeviceProfile("small", "cpu", cores=4, freq_ghz=1.0))
        assert prior_scale(src, tgt) == pytest.approx(4.0)

    def test_one_session_two_device_tags_no_cache_aliasing(self):
        """Regression: the in-process latency cache must not serve the
        source device's measurement to a device-tagged setting."""
        g = tiny_graph()
        calls = []
        sess = ProfileSession(warmup=0, inner=1, repeats=1,
                              e2e_inner=1, e2e_repeats=1,
                              latency_transform=lambda kind, s:
                                  (calls.append(kind) or float(len(calls))))
        tagged = DeviceSetting("sim", "float32", "op_by_op", device="sim")
        lat_a = sess.measure_op(g, g.nodes[0], SRC)
        n = sess.measured_ops
        lat_b = sess.measure_op(g, g.nodes[0], tagged)
        assert sess.measured_ops == n + 1      # re-measured, not aliased
        assert (lat_a, lat_b) == (1.0, 2.0)
        # Repeat queries hit their own per-device cache entries.
        assert sess.measure_op(g, g.nodes[0], SRC) == 1.0
        assert sess.measure_op(g, g.nodes[0], tagged) == 2.0
        assert sess.measured_ops == n + 1


# ---------------------------------------------------------------------------
# Calibration maps (satellite: bit-exact JSON round-trip)
# ---------------------------------------------------------------------------

class TestLatencyMap:
    def grid(self):
        return np.geomspace(1e-6, 1e-1, 64)

    def test_affine_recovery(self):
        src = np.geomspace(1e-5, 1e-2, 24)
        tgt = np.exp(0.7) * src ** 1.1
        m = fit_latency_map(src, tgt, slope_shrink=0.0)
        assert m.kind == "affine_log"
        assert m.a == pytest.approx(0.7, abs=1e-9)
        assert m.b == pytest.approx(1.1, abs=1e-9)
        np.testing.assert_allclose(m.apply(src), tgt, rtol=1e-9)

    def test_single_pair_is_ratio(self):
        m = fit_latency_map([1e-4], [3e-4])
        assert m.b == 1.0
        assert m.apply_scalar(2e-4) == pytest.approx(6e-4)

    def test_slope_shrinkage_on_tiny_samples(self):
        src = np.array([1e-5, 1e-3])
        tgt = np.exp(0.0) * src ** 1.5         # 2 pairs of a steep map
        m = fit_latency_map(src, tgt)           # default shrink
        assert 1.0 < m.b < 1.5                  # pulled toward a ratio

    def test_isotonic_fallback_monotone(self):
        # Anti-correlated pairs: the log-affine slope goes negative and
        # the fit must fall back to a monotone isotonic map.
        src = np.array([1e-5, 1e-4, 1e-3, 1e-2])
        tgt = np.array([4e-4, 3e-4, 2e-4, 1e-4])
        m = fit_latency_map(src, tgt)
        assert m.kind == "isotonic_log"
        out = m.apply(self.grid())
        assert np.all(np.diff(out) >= 0)

    @pytest.mark.parametrize("case", ["affine", "isotonic", "ratio"])
    def test_json_roundtrip_bit_exact(self, case):
        if case == "affine":
            m = fit_latency_map(np.geomspace(1e-5, 1e-2, 10),
                                np.exp(0.31) * np.geomspace(1e-5, 1e-2, 10) ** 0.93)
        elif case == "isotonic":
            m = fit_latency_map([1e-5, 1e-4, 1e-3], [3e-4, 2e-4, 1e-4])
        else:
            m = scale_map(2.7182818)
        blob = json.dumps(m.to_json())          # through actual JSON text
        m2 = LatencyMap.from_json(json.loads(blob))
        assert m2 == m
        assert np.array_equal(m.apply(self.grid()), m2.apply(self.grid()))


class TestCalibratedPredictor:
    def fitted(self):
        rng = np.random.default_rng(0)
        x = np.abs(rng.standard_normal((60, 5))) * np.array([1e9, 1e6, 64, 64, 3])
        y = np.maximum(x[:, 0] / 50e9, x[:, 1] / 10e9) + 5e-6
        base = make_predictor("gbdt", n_stages=20).fit(x, y)
        m = fit_latency_map(y, np.exp(0.4) * y ** 1.05)
        from repro.transfer import CalibratedPredictor
        return CalibratedPredictor.wrap(base, m), x, base, m

    def test_predict_composes(self):
        cal, x, base, m = self.fitted()
        np.testing.assert_array_equal(cal.predict(x),
                                      np.maximum(m.apply(base.predict(x)), 0.0))

    def test_roundtrip_bit_exact(self):
        cal, x, _, _ = self.fitted()
        cal2 = load_predictor(json.loads(json.dumps(cal.to_json())))
        assert np.array_equal(cal.predict(x), cal2.predict(x))
        assert np.array_equal(cal.predict_oracle(x), cal2.predict_oracle(x))

    def test_bank_roundtrip_with_calibrated(self):
        cal, x, _, _ = self.fitted()
        bank = PredictorBank(setting="simdev:float32/op_by_op",
                             overhead=1e-4, op_sum_scale=1.2)
        bank.predictors["conv2d"] = cal
        bank2 = PredictorBank.from_json(json.loads(json.dumps(bank.to_json())))
        assert np.array_equal(bank.predictors["conv2d"].predict(x),
                              bank2.predictors["conv2d"].predict(x))

    def test_no_stacking(self):
        from repro.transfer import CalibratedPredictor, identity_map
        cal, _, _, _ = self.fitted()
        with pytest.raises(TypeError):
            CalibratedPredictor.wrap(cal, identity_map())


# ---------------------------------------------------------------------------
# Sampler (satellite: determinism under a fixed seed)
# ---------------------------------------------------------------------------

class TestSampler:
    def test_deterministic_given_seed(self, source):
        store, _, _, bank = source
        p1 = plan_samples(store, SRC, 24, bank=bank, seed=3)
        p2 = plan_samples(store, SRC, 24, bank=bank, seed=3)
        assert p1.signatures == p2.signatures
        assert p1.to_json() == p2.to_json()

    def test_budget_respected_no_duplicates(self, source):
        store, _, _, bank = source
        for k in (1, 7, 30):
            plan = plan_samples(store, SRC, k, bank=bank)
            assert len(plan.records) <= k
            assert len(set(plan.signatures)) == len(plan.records)

    def test_coverage_first(self, source):
        store, _, _, bank = source
        types = store.op_types(SRC)
        plan = plan_samples(store, SRC, len(types), bank=bank)
        # A budget of exactly n_types buys one sample of every type.
        assert sorted(plan.per_type) == types
        assert all(v == 1 for v in plan.per_type.values())

    def test_greedy_stage_takes_most_expensive(self, source):
        store, _, _, _ = source
        records = store.op_records(SRC)
        types = store.op_types(SRC)
        budget = 4 * len(types) + 8      # past full stratified coverage
        plan = plan_samples(store, SRC, budget, bank=None, strata=4)
        assert plan.n_greedy > 0
        # With measured-latency scores, the single most expensive op
        # must be in the plan (stage 2 picks by descending score).
        top = max(records, key=lambda r: r.latency_s)
        assert top.signature in plan.signatures

    def test_op_types_filter(self, source):
        """Budget must not be spent on types the bank cannot calibrate."""
        store, _, _, _ = source
        allowed = set(store.op_types(SRC)[:2])
        plan = plan_samples(store, SRC, 20, op_types=allowed)
        assert plan.records and set(plan.per_type) <= allowed

    def test_oversized_budget_takes_everything(self, source):
        store, _, _, bank = source
        plan = plan_samples(store, SRC, 10 ** 6, bank=bank)
        assert len(plan.records) == len(store.op_records(SRC))

    def test_empty_store(self):
        plan = plan_samples(ProfileStore(), SRC, 8)
        assert plan.records == []


# ---------------------------------------------------------------------------
# ProfileStore.compact (satellite)
# ---------------------------------------------------------------------------

class TestStoreCompact:
    def test_compact_dedups_file(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        store = ProfileStore(path)
        sess = CostModelProfileSession(store=store, seed=1)
        sess.profile_graph(tiny_graph("a"), SRC)
        store.close()
        # Simulate overlapping writers: duplicate every line.
        with open(path) as f:
            lines = f.readlines()
        with open(path, "a") as f:
            f.writelines(lines)

        dup = ProfileStore(path)
        n_records = dup.stats()["op_records"] + dup.stats()["arch_records"]
        assert dup.stats()["file_lines"] == 2 * len(lines)
        out = dup.compact()
        assert out == {"kept": n_records, "dropped": 2 * len(lines) - n_records}
        assert dup.stats()["file_lines"] == n_records

        # Reload: identical content, one line per record.
        back = ProfileStore(path)
        assert back.stats()["file_lines"] == n_records
        rec0 = dup.op_records(SRC)[0]
        assert back.get_op(SRC, rec0.signature).latency_s == rec0.latency_s
        assert len(back.arch_records(SRC)) == len(dup.arch_records(SRC))

    def test_compact_merges_foreign_appends(self, tmp_path):
        """compact() must not clobber records another writer appended
        to the same file after this store loaded."""
        path = str(tmp_path / "store.jsonl")
        s1 = ProfileStore(path)
        sess1 = CostModelProfileSession(store=s1, seed=1)
        sess1.profile_graph(tiny_graph("a"), SRC)
        s1.flush()

        s2 = ProfileStore(path)                 # second writer, same file
        sess2 = CostModelProfileSession(store=s2, seed=1)
        sess2.profile_graph(tiny_graph("b", ch=8), SRC)
        s2.close()

        s1.compact()                            # stale view of the file
        back = ProfileStore(path)
        assert len(back.arch_records(SRC)) == 2
        assert back.stats()["op_records"] == s2.stats()["op_records"]

    def test_compact_in_memory_noop(self):
        store = ProfileStore()
        assert store.compact() == {"kept": 0, "dropped": 0}

    def test_append_after_compact(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        store = ProfileStore(path)
        sess = CostModelProfileSession(store=store, seed=1)
        sess.profile_graph(tiny_graph("a"), SRC)
        store.compact()
        sess.profile_graph(tiny_graph("b", ch=8), SRC)   # reopens the file
        back = ProfileStore(path)
        assert back.stats()["op_records"] == store.stats()["op_records"]


# ---------------------------------------------------------------------------
# PredictorHub.load hardening (satellite)
# ---------------------------------------------------------------------------

class TestHubLoadHardening:
    def test_skips_non_bank_and_malformed(self, tmp_path, source):
        store, _, _, _ = source
        root = str(tmp_path / "hub")
        hub = PredictorHub(root)
        hub.train(store, SRC, "lasso", min_samples=3)
        # A calibration artifact, a malformed bank file, and a bank-named
        # file with a non-bank schema all live in the same directory.
        with open(os.path.join(root, "calibration__simdev.json"), "w") as f:
            json.dump(scale_map(2.0).to_json(), f)
        with open(os.path.join(root, "bank__broken__x__gbdt.json"), "w") as f:
            f.write("{not json")
        with open(os.path.join(root, "bank__float32__op_by_op__rf.json"), "w") as f:
            json.dump({"something": "else"}, f)

        hub2 = PredictorHub.load(root)
        assert list(hub2.banks) == [("float32/op_by_op", "lasso")]


# ---------------------------------------------------------------------------
# TransferEngine (tentpole) — budget curve, registration, determinism
# ---------------------------------------------------------------------------

def fresh_hub(source):
    _, _, _, bank = source
    hub = PredictorHub()
    hub.banks[(setting_key(SRC), "gbdt")] = bank
    return hub


DEVICE = SyntheticDevice("simdev", seed=7, noise=0.1, curvature=0.15)


class TestTransferEngine:
    def oracle(self, source):
        """Fully-profiled target: (truth e2e by name, oracle MAPE)."""
        store, graphs, _, _ = source
        osess = ReplayProfileSession(store, DEVICE, SRC, store=ProfileStore())
        truth = {g.name: osess.profile_graph(g, TGT).e2e_s for g in graphs}
        hub = PredictorHub()
        hub.train(osess.store, TGT, "gbdt", hparams={"n_stages": 50},
                  min_samples=3,
                  fingerprints=[g.fingerprint() for g in graphs[:9]])
        svc = LatencyService(hub, predictor="gbdt")
        test = graphs[9:]
        o_mape = mape([truth[g.name] for g in test],
                      [svc.predict_e2e(g, TGT).e2e_s for g in test])
        return truth, o_mape

    def adapt_and_eval(self, source, truth, budget):
        store, graphs, _, _ = source
        hub = fresh_hub(source)
        session = ReplayProfileSession(store, DEVICE, SRC)
        result = TransferEngine(SRC, TGT, family="gbdt", seed=0).adapt(
            store, hub, session, budget)
        assert result.n_measurements <= budget
        assert session.measured_ops + session.measured_graphs <= budget
        svc = LatencyService(hub, predictor="gbdt")
        test = graphs[9:]
        m = mape([truth[g.name] for g in test],
                 [svc.predict_e2e(g, TGT).e2e_s for g in test])
        return result, m

    def test_register_and_serve_zero_code_changes(self, source):
        store, graphs, _, _ = source
        hub = fresh_hub(source)
        result = TransferEngine(SRC, TGT, family="gbdt", seed=0).adapt(
            store, hub, ReplayProfileSession(store, DEVICE, SRC), 16)
        assert result.target_key == "simdev:float32/op_by_op"
        assert hub.get(TGT, "gbdt") is result.bank
        svc = LatencyService(hub, default_setting=SRC, predictor="gbdt")
        r_src = svc.predict_e2e(graphs[0])
        r_tgt = svc.predict_e2e(graphs[0], TGT)     # same call, new device
        assert r_tgt.setting == "simdev:float32/op_by_op"
        assert r_tgt.e2e_s > 0 and r_tgt.e2e_s != r_src.e2e_s
        assert ("simdev:float32/op_by_op", "gbdt") in svc.available()

    def test_budget_curve_and_oracle_gap(self, source):
        truth, o_mape = self.oracle(source)
        r8, m8 = self.adapt_and_eval(source, truth, 8)
        r64, m64 = self.adapt_and_eval(source, truth, 64)
        # The issue's acceptance bar: more budget is never worse, and
        # K=64 lands within 2× of the fully-profiled oracle bank.
        assert m64 <= m8
        assert m64 <= 2.0 * o_mape
        assert r64.n_measurements <= 64

    def test_adapt_deterministic(self, source):
        store, graphs, _, _ = source
        outs = []
        for _ in range(2):
            hub = fresh_hub(source)
            TransferEngine(SRC, TGT, family="gbdt", seed=0).adapt(
                store, hub, ReplayProfileSession(store, DEVICE, SRC), 24)
            svc = LatencyService(hub, predictor="gbdt")
            outs.append([svc.predict_e2e(g, TGT).e2e_s for g in graphs])
        assert outs[0] == outs[1]

    def test_same_key_rejected(self, source):
        with pytest.raises(ValueError):
            TransferEngine(SRC, DeviceSetting("other", "float32", "op_by_op"))

    def test_missing_source_bank_raises(self, source):
        store, _, _, _ = source
        with pytest.raises(ValueError):
            TransferEngine(SRC, TGT, family="mlp").adapt(
                store, PredictorHub(), ReplayProfileSession(store, DEVICE, SRC), 8)

    def test_real_session_with_probe_graphs(self):
        """The wall-clock path: a plain ProfileSession (2× latency
        transform) as the target, signatures located in probe graphs."""
        graphs = [tiny_graph("a", ch=4), tiny_graph("b", ch=8)]
        store = ProfileStore()
        # repeats=3: time_callable takes min-over-repeats, so a single
        # scheduler hiccup can't inflate one op measurement (with
        # repeats=1 a ~5ms preemption skews the 2-point overhead fit
        # negative and the transferred e2e prediction goes < 0).
        src_sess = ProfileSession(warmup=0, inner=1, repeats=3,
                                  e2e_inner=1, e2e_repeats=3, store=store)
        for g in graphs:
            src_sess.profile_graph(g, SRC)
        hub = PredictorHub()
        hub.train(store, SRC, "lasso", min_samples=2)

        target = DeviceSetting("slow2x", "float32", "op_by_op", device="slow2x")
        tgt_sess = ProfileSession(
            warmup=0, inner=1, repeats=3,
            latency_transform=lambda kind, s: 2.0 * s)
        engine = TransferEngine(SRC, target, family="lasso", seed=0,
                                probe_graphs=graphs)
        result = engine.adapt(store, hub, tgt_sess, 4)
        assert result.n_op_measurements <= 4
        assert result.composition == "ratio-scaled"
        svc = LatencyService(hub, predictor="lasso")
        assert svc.predict_e2e(graphs[0], target).e2e_s > 0

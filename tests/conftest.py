"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device tests spawn subprocesses (test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

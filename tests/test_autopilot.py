"""Closed-loop observability: timeline, alert engine, autopilot.

Covers the three `repro.obs` control-plane pieces added for ROADMAP
item 2 — `MetricsTimeline`, `AlertEngine`/`AlertRule`/`AuditLog`,
`RecalibrationAutopilot` — plus the satellites that ride along
(`DriftMonitor.worst_cells`, `FlightRecorder.dumps_dropped`,
focus-aware transfer planning, `SyntheticDevice.warp_shift`).

The centerpiece is the deterministic closed loop: a seeded synthetic
drift (warp shift) pushes the drift score over threshold, the rule
sustains and fires, the autopilot recalibrates the offending op types
with a bounded budget and rolls the refreshed bank over — and the whole
sequence is reconstructable from the audit log + span tree alone,
bit-identical across two `ManualClock` replays.  A second test runs the
same loop while a TCP flood is in flight and checks no request is lost
or double-answered across the rollover.
"""
import json
import threading
from collections import Counter

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    HAS_HYPOTHESIS = False

from repro.core.dataset import synthetic_graphs
from repro.core.profiler import DeviceSetting
from repro.obs import (AlertEngine, AlertRule, AuditLog, AutopilotConfig,
                       DriftMonitor, FlightRecorder, MetricsRegistry,
                       MetricsTimeline, Observability, RecalibrationAutopilot,
                       attach_session_drift, to_prometheus)
from repro.pipeline import LatencyService, PredictorHub, ProfileStore
from repro.pipeline.store import setting_key
from repro.rpc.batcher import BatchPolicy, ManualClock
from repro.rpc.client import LatencyClient
from repro.rpc.protocol import RPCError
from repro.rpc.server import LatencyRPCServer
from repro.transfer import (CostModelProfileSession, ReplayProfileSession,
                            SyntheticDevice, TransferEngine)

SRC = DeviceSetting("cpu_f32", "float32", "op_by_op")
TGT = DeviceSetting("edge_f32", "float32", "op_by_op", device="edge0")
DEVICE = SyntheticDevice("edge0", seed=7, noise=0.05, curvature=0.1)
TGT_KEY = "edge0:float32/op_by_op"


def build_fleet(n_graphs=12, seed=1):
    """Source store + hub with a source gbdt bank and a calibrated
    target bank onboarded against the *pre-drift* device."""
    graphs = synthetic_graphs(n_graphs, resolution=16)
    store = ProfileStore()
    sess = CostModelProfileSession(store=store, seed=seed)
    for g in graphs:
        sess.profile_graph(g, SRC)
    hub = PredictorHub()
    hub.train(store, SRC, "gbdt", hparams={"n_stages": 30}, min_samples=3)
    TransferEngine(SRC, TGT, family="gbdt", seed=0).adapt(
        store, hub, ReplayProfileSession(store, DEVICE, SRC), 32)
    return store, graphs, hub


def observe_round(store, svc, obs, device, n=48):
    """One profiling round against the (possibly drifted) device: fresh
    session each time — a reused session's latency cache would replay
    the pre-drift values and hide the drift."""
    sess = ReplayProfileSession(store, device, SRC)
    attach_session_drift(sess, svc, obs.drift)
    for rec in store.op_records(SRC)[:n]:
        sess.measure_record(rec, TGT)


# ---------------------------------------------------------------------------
# MetricsTimeline
# ---------------------------------------------------------------------------

class TestTimeline:
    def test_interval_gating_and_force(self):
        clock = ManualClock()
        tl = MetricsTimeline(clock=clock, interval=2, capacity=16)
        val = {"x": 1.0}
        tl.track("x", lambda: val["x"])
        assert tl.sample() is not None           # first sample always lands
        assert tl.sample() is None               # same instant: gated
        assert tl.stats()["skipped"] == 1
        clock.advance(1)
        assert tl.sample() is None               # under the interval
        assert tl.sample(force=True) is not None  # force bypasses the gate
        clock.advance(2)
        val["x"] = 5.0
        p = tl.sample()
        assert p["v"]["x"] == 5
        assert tl.latest("x") == 5
        assert tl.samples == 3

    def test_capacity_bounds_ring_and_points_since(self):
        clock = ManualClock()
        tl = MetricsTimeline(clock=clock, interval=1, capacity=4)
        tl.track("x", lambda: clock.now())
        for _ in range(10):
            clock.advance(1)
            tl.sample()
        assert len(tl.points()) == 4             # ring evicted the rest
        assert tl.samples == 10
        fresh, total = tl.points_since(8)        # only the still-held tail
        assert total == 10
        assert [p["t"] for p in fresh] == [9, 10]
        fresh, total = tl.points_since(2)        # older points were evicted
        assert [p["t"] for p in fresh] == [7, 8, 9, 10]

    def test_probe_error_omits_value_and_counts(self):
        tl = MetricsTimeline(clock=ManualClock(), interval=1)
        tl.track("good", lambda: 1.0)

        def bad():
            raise RuntimeError("probe down")
        tl.track("bad", bad)
        p = tl.sample()
        assert p["v"] == {"good": 1}             # bad omitted, not poisoned
        assert tl.stats()["probe_errors"] == 1

    def test_windows_alignment_and_conservation(self):
        clock = ManualClock()
        tl = MetricsTimeline(clock=clock, interval=1, capacity=64)
        seq = iter([3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0])
        tl.track("x", lambda: next(seq))
        for _ in range(7):
            clock.advance(1)
            tl.sample()                          # t = 1..7, width 3 windows
        ws = tl.windows("x", 3.0)
        assert [w["start"] for w in ws] == [0, 3, 6]
        assert [w["end"] for w in ws] == [3, 6, 9]
        assert sum(w["count"] for w in ws) == 7  # conservation
        w0 = ws[0]                               # t=1,2 -> values 3,1
        assert (w0["min"], w0["max"], w0["last"]) == (1, 3, 1)
        assert ws[-1]["last"] == 2               # t=7 -> 2

    def test_json_round_trip_bit_stable(self):
        clock = ManualClock()
        tl = MetricsTimeline(clock=clock, interval=1, capacity=8)
        tl.track("x", lambda: 0.5 * clock.now())
        for _ in range(5):
            clock.advance(1)
            tl.sample()
        text = tl.json_text()
        back = MetricsTimeline.from_json(json.loads(text), clock=clock)
        assert back.json_text() == text          # byte-stable round trip
        assert back.series("x") == tl.series("x")

    def test_track_registry_probes(self):
        reg = MetricsRegistry()
        reg.inc("reqs_total", 3, svc="a")
        reg.set("depth", 7.0)
        reg.histogram("lat", buckets=(0.1, 1.0))
        reg.observe("lat", 0.5)
        tl = MetricsTimeline(clock=ManualClock(), interval=1)
        tl.track_counter(reg, "reqs_total", svc="a")
        tl.track_gauge(reg, "depth")
        tl.track_quantile(reg, "lat", 0.5)
        p = tl.sample()
        assert p["v"]["reqs_total"] == 3
        assert p["v"]["depth"] == 7
        assert "lat_p50" in p["v"]


if HAS_HYPOTHESIS:
    class TestTimelineProperties:
        @settings(max_examples=50, deadline=None)
        @given(deltas=st.lists(st.integers(min_value=0, max_value=5),
                               min_size=1, max_size=40),
               width=st.integers(min_value=1, max_value=7))
        def test_window_conservation_and_monotone_edges(self, deltas, width):
            clock = ManualClock()
            tl = MetricsTimeline(clock=clock, interval=1, capacity=4096)
            tl.track("x", lambda: float(clock.now() % 13))
            for d in deltas:
                clock.advance(d)
                tl.sample()
            ws = tl.windows("x", float(width))
            # Conservation: every held point lands in exactly one window.
            assert sum(w["count"] for w in ws) == len(tl.series("x"))
            for w in ws:
                assert w["end"] - w["start"] == width
                assert w["start"] % width == 0   # absolute alignment
                assert w["min"] <= w["last"] <= w["max"]
                assert w["count"] >= 1           # empty windows are omitted
            # Monotone, non-overlapping edges.
            for a, b in zip(ws, ws[1:]):
                assert a["end"] <= b["start"]


# ---------------------------------------------------------------------------
# AlertRule / AlertEngine / AuditLog
# ---------------------------------------------------------------------------

def run_rule(rule, samples):
    """Drive one rule over a scripted [(t, value)] list; returns events."""
    clock = ManualClock()
    tl = MetricsTimeline(clock=clock, interval=0.5, capacity=4096)
    cur = {"v": 0.0}
    tl.track(rule.series, lambda: cur["v"])
    eng = AlertEngine(tl, [rule])
    out = []
    for t, v in samples:
        clock.advance(t - clock.now())
        cur["v"] = v
        tl.sample(force=True)
        out.extend(eng.evaluate())
    return out, eng


class TestAlertRules:
    def test_exactly_at_threshold_does_not_fire(self):
        rule = AlertRule("r", series="s", threshold=1.0, sustain=1)
        events, eng = run_rule(rule, [(1, 1.0), (2, 1.0), (3, 1.0)])
        assert events == []
        assert eng.firing() == []
        events, _ = run_rule(AlertRule("r", series="s", threshold=1.0),
                             [(1, 1.0000001)])
        assert [e["kind"] for e in events] == ["fire"]

    def test_sustain_counts_consecutive_breaches(self):
        rule = AlertRule("r", series="s", threshold=1.0, sustain=3)
        # Breach, breach, dip (streak resets), breach x3 -> fire on the 6th.
        events, _ = run_rule(rule, [(1, 2), (2, 2), (3, 0.5),
                                    (4, 2), (5, 2), (6, 2)])
        assert [(e["kind"], e["t"]) for e in events] == [("fire", 6)]

    def test_sustain_resets_on_gap(self):
        rule = AlertRule("r", series="s", threshold=1.0, sustain=3,
                         max_gap=2.0)
        # Two breaches, then a 5s hole in the series: excursion over.
        events, _ = run_rule(rule, [(1, 2), (2, 2), (7, 2), (8, 2)])
        assert events == []
        events, _ = run_rule(rule, [(1, 2), (2, 2), (7, 2), (8, 2), (9, 2)])
        assert [e["kind"] for e in events] == ["fire"]

    def test_hysteresis_holds_then_rearms(self):
        rule = AlertRule("r", series="s", threshold=1.0, sustain=2,
                         clear_threshold=0.5)
        events, eng = run_rule(rule, [
            (1, 2), (2, 2),          # fire at t=2
            (3, 0.8),                # inside the band: still firing
            (4, 0.5),                # at clear level: clears (not strict >)
            (5, 2), (6, 2),          # re-armed: fires again
        ])
        assert [(e["kind"], e["t"]) for e in events] == \
            [("fire", 2), ("clear", 4), ("fire", 6)]
        assert eng.firing() == ["r"]

    def test_clear_threshold_must_widen_band(self):
        with pytest.raises(ValueError):
            AlertRule("r", series="s", threshold=1.0, clear_threshold=2.0)
        with pytest.raises(ValueError):
            AlertRule("r", series="s", threshold=1.0, op="<",
                      clear_threshold=0.5)

    def test_delta_mode_alerts_on_rate(self):
        rule = AlertRule("r", series="s", threshold=5.0, mode="delta")
        # Counter-style series: only the +6 jump breaches.
        events, _ = run_rule(rule, [(1, 0), (2, 2), (3, 4), (4, 10)])
        assert [(e["kind"], e["value"]) for e in events] == [("fire", 6)]

    def test_below_rule_and_duplicate_name_rejected(self):
        rule = AlertRule("floor", series="s", threshold=1.0, op="<")
        events, eng = run_rule(rule, [(1, 2.0), (2, 0.5)])
        assert [e["kind"] for e in events] == ["fire"]
        with pytest.raises(ValueError):
            eng.add_rule(AlertRule("floor", series="s", threshold=9.0))

    def test_events_are_trace_linked_and_audited(self):
        clock = ManualClock()
        obs = Observability(clock=clock, seed=5)
        tl = MetricsTimeline(clock=clock, interval=1)
        tl.track("s", lambda: 2.0)
        eng = AlertEngine(tl, [AlertRule("r", series="s", threshold=1.0)],
                          obs=obs)
        clock.advance(1)
        tl.sample()
        (ev,) = eng.evaluate()
        assert ev["tid"] is not None and ev["sid"] is not None
        spans = obs.tracer.export()
        assert any(s["name"] == "alert.fire" and s["sid"] == ev["sid"]
                   for s in spans)
        (audited,) = eng.audit.events("alert.fire")
        assert audited["tid"] == ev["tid"]
        # A fire also dumps the flight recorder.
        assert obs.recorder.last_dump()["reason"] == "alert"


if HAS_HYPOTHESIS:
    class TestAlertProperties:
        @settings(max_examples=60, deadline=None)
        @given(values=st.lists(st.sampled_from([0.0, 0.5, 1.0, 1.5, 2.0]),
                               min_size=1, max_size=30),
               sustain=st.integers(min_value=1, max_value=4))
        def test_fire_clear_alternate_and_sustain_holds(self, values,
                                                        sustain):
            rule = AlertRule("r", series="s", threshold=1.0, sustain=sustain,
                             clear_threshold=0.5)
            samples = [(i + 1, v) for i, v in enumerate(values)]
            events, _ = run_rule(rule, samples)
            kinds = [e["kind"] for e in events]
            # fire/clear strictly alternate, starting with fire.
            assert kinds == (["fire", "clear"] * len(kinds))[:len(kinds)]
            for ev in events:
                i = int(ev["t"]) - 1
                if ev["kind"] == "fire":
                    # The sustain points up to the fire all strictly breach.
                    window = values[max(0, i - sustain + 1):i + 1]
                    assert len(window) == sustain
                    assert all(v > 1.0 for v in window)
                else:
                    assert values[i] <= 0.5
            if all(v <= 1.0 for v in values):
                assert events == []


class TestAuditLog:
    def test_bounded_with_monotone_seq_and_dropped(self):
        log = AuditLog(capacity=4)
        for i in range(7):
            log.record("k", float(i), i=i)
        evs = log.events()
        assert len(evs) == 4
        assert [e["seq"] for e in evs] == [4, 5, 6, 7]   # monotone, gapless
        assert log.dropped == 3
        assert log.stats() == {"events": 4, "seq": 7, "dropped": 3}
        json.loads(log.json_text())                       # canonical JSON

    def test_kind_filter_and_sorted_fields(self):
        log = AuditLog()
        log.record("a", 1.0, z=1, b=2)
        log.record("b", 2.0)
        assert [e["kind"] for e in log.events("a")] == ["a"]
        assert list(log.events("a")[0]) == ["seq", "kind", "t", "b", "z"]


# ---------------------------------------------------------------------------
# Satellites: worst_cells, dumps_dropped, warp_shift, focus planning
# ---------------------------------------------------------------------------

class TestWorstCells:
    def test_shape_order_and_gating(self):
        m = DriftMonitor(threshold=0.25, min_count=2)
        for _ in range(3):
            m.observe("dev", "conv2d", 0.01, 0.02)       # |mean| = log 2
            m.observe("dev", "dense", 0.01, 0.015)       # |mean| = log 1.5
        m.observe("dev", "relu", 0.01, 0.09)             # n=1: gated out
        cells = m.worst_cells(5)
        assert [set(c) for c in cells] == \
            [{"setting", "op_type", "n", "mean", "score"}] * 2
        assert [c["op_type"] for c in cells] == ["conv2d", "dense"]
        assert cells[0]["score"] > cells[1]["score"] > 1.0
        assert m.worst_cells(1) == cells[:1]
        assert m.worst_cells(0) == []

    def test_ties_break_deterministically(self):
        m = DriftMonitor(threshold=0.25, min_count=1)
        m.observe("b", "z", 0.01, 0.02)
        m.observe("a", "y", 0.01, 0.02)                  # identical score
        assert [(c["setting"], c["op_type"]) for c in m.worst_cells(2)] == \
            [("a", "y"), ("b", "z")]


class TestFlightRecorderDrops:
    def test_dump_overflow_counted(self):
        fr = FlightRecorder(capacity=8, max_dumps=3)
        for i in range(5):
            fr.dump(f"r{i}")
        assert len(fr.dumps) == 3
        assert [d["reason"] for d in fr.dumps] == ["r2", "r3", "r4"]
        assert fr.dumps_dropped == 2
        assert fr.stats()["dumps_dropped"] == 2

    def test_surfaced_through_obs_snapshot(self):
        obs = Observability()
        assert obs.snapshot()["collected"]["flight_recorder"][
            "dumps_dropped"] == 0


class TestPrometheusHelp:
    def build(self):
        reg = MetricsRegistry()
        reg.inc("rpc_batcher_submitted_total", 5, batcher="batcher0")
        reg.inc("obs_flight_dumps_total", 2, reason="alert")
        reg.set("rpc_batcher_queue_depth", 3, batcher="batcher0")
        reg.histogram("rpc_batcher_flush_duration",
                      buckets=(0.001, 0.01, 0.1))
        reg.observe("rpc_batcher_flush_duration", 0.005, batcher="batcher0")
        reg.inc("custom_widget_total", 1)
        return reg

    def test_golden_bytes(self):
        import os
        golden = os.path.join(os.path.dirname(__file__), "golden",
                              "metrics_prometheus.txt")
        with open(golden) as f:
            want = f.read()
        text = to_prometheus(self.build().snapshot(include_collected=False),
                             now=1234.5)
        assert text == want                      # byte-pinned exposition

    def test_help_lines_and_scrape_timestamp(self):
        from repro.obs import METRIC_HELP
        text = to_prometheus(self.build().snapshot(include_collected=False),
                             now=1234.5)
        # Curated description for every known metric...
        assert ("# HELP rpc_batcher_submitted_total "
                + METRIC_HELP["rpc_batcher_submitted_total"]) in text
        # ...readable fallback (not an empty HELP) for unknown ones.
        assert "# HELP custom_widget_total custom widget total." in text
        assert "repro_scrape_timestamp_seconds 1234.5" in text
        # Every exposed family carries a HELP line right before TYPE.
        lines = text.splitlines()
        for i, line in enumerate(lines):
            if line.startswith("# TYPE"):
                assert lines[i - 1].startswith(
                    "# HELP " + line.split()[2] + " ")
        # No timestamp gauge when no clock reading is supplied.
        untimed = to_prometheus(self.build().snapshot(
            include_collected=False))
        assert "repro_scrape_timestamp_seconds" not in untimed

    def test_help_map_matches_instrumented_names(self):
        """Every curated HELP entry names a metric the codebase actually
        emits — descriptions must not rot as metrics are renamed."""
        import os
        import re
        from repro.obs import METRIC_HELP
        root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
        literals = set()
        for dirpath, _dirs, files in os.walk(root):
            for fn in files:
                if fn.endswith(".py"):
                    with open(os.path.join(dirpath, fn)) as f:
                        literals.update(
                            re.findall(r'"([a-z0-9_]+)"', f.read()))
        for name in METRIC_HELP:
            if name == "repro_scrape_timestamp_seconds":
                continue                         # synthesized at export
            assert name in literals, f"METRIC_HELP orphan: {name}"


class TestWarpShift:
    def test_pure_scale_is_exact_multiplier(self):
        warped = DEVICE.warp_shift(scale=2.0)
        base = DEVICE.op_latency("conv2d", "sig0", 0.01)
        assert warped.op_latency("conv2d", "sig0", 0.01) == \
            pytest.approx(2.0 * base, rel=1e-12)
        assert DEVICE.base_scale == warped.base_scale / 2.0  # original frozen

    def test_seed_offset_rerolls_per_type_warp(self):
        rerolled = DEVICE.warp_shift(seed_offset=11)
        a = DEVICE.op_latency("conv2d", "sig0", 0.01)
        b = rerolled.op_latency("conv2d", "sig0", 0.01)
        assert a != b                                    # new device persona
        # Deterministic: same shift twice is the same device.
        again = DEVICE.warp_shift(seed_offset=11)
        assert again.op_latency("conv2d", "sig0", 0.01) == b

    def test_invalid_scale_rejected(self):
        with pytest.raises(ValueError):
            DEVICE.warp_shift(scale=0.0)


class TestFocusPlanning:
    @pytest.fixture(scope="class")
    def source(self):
        return build_fleet()

    def test_focus_concentrates_budget(self, source):
        store, _graphs, hub = source
        bank = hub.get(SRC, "gbdt")
        focus_type = Counter(r.op_type for r in
                             store.op_records(SRC)).most_common(1)[0][0]
        plain = TransferEngine(SRC, TGT, family="gbdt", seed=0)
        focused = TransferEngine(SRC, TGT, family="gbdt", seed=0,
                                 focus_op_types=[focus_type], focus_frac=0.5)
        n = 16
        p0 = plain._plan_ops(store, bank, n)
        p1 = focused._plan_ops(store, bank, n)
        count = lambda plan: sum(1 for r in plan.records
                                 if r.op_type == focus_type)
        assert count(p1) >= n // 2                       # focus share honored
        assert count(p1) > count(p0)
        assert len(p1.records) <= n
        sigs = [r.signature for r in p1.records]
        assert len(sigs) == len(set(sigs))               # merge deduped

    def test_focus_validation_and_result_field(self, source):
        store, _graphs, hub = source
        with pytest.raises(ValueError):
            TransferEngine(SRC, TGT, focus_op_types=["x"], focus_frac=0.0)
        ft = store.op_types(SRC)[0]
        eng = TransferEngine(SRC, TGT, family="gbdt", seed=0,
                             focus_op_types=[ft])
        scratch = PredictorHub()
        scratch.register(SRC, "gbdt", hub.get(SRC, "gbdt"))
        res = eng.adapt(store, scratch,
                        ReplayProfileSession(store, DEVICE, SRC), 24)
        assert res.focus_op_types == [ft]
        assert res.to_json()["focus_op_types"] == [ft]
        assert res.n_measurements <= 24


# ---------------------------------------------------------------------------
# The closed loop, deterministic and bit-replayable
# ---------------------------------------------------------------------------

class TestClosedLoop:
    def run_once(self):
        clock = ManualClock()
        obs = Observability(clock=clock, seed=21, drift_threshold=0.5,
                            drift_min_count=4)
        store, graphs, hub = build_fleet()
        svc = LatencyService(hub, default_setting=SRC, predictor="gbdt",
                             obs=obs)
        tl = MetricsTimeline(clock=clock, interval=1, capacity=256)
        tl.track("drift_score", obs.drift.score)
        eng = AlertEngine(tl, [AlertRule("drift", series="drift_score",
                                         threshold=1.0, sustain=3)], obs=obs)
        drifted = DEVICE.warp_shift(scale=2.4, seed_offset=3)
        ap = RecalibrationAutopilot(
            obs, eng, hub, store, SRC,
            config=AutopilotConfig(budget_k=48, top_k_cells=3, cooldown=4.0,
                                   window=64.0, max_actions_per_window=2,
                                   seed=0))
        ap.register_device(
            TGT, lambda: ReplayProfileSession(store, drifted, SRC))
        epoch0 = hub.epoch_of(TGT, "gbdt")
        for _ in range(10):
            observe_round(store, svc, obs, drifted)
            clock.advance(1)
            ap.step()
        return {
            "epoch0": epoch0, "epoch1": hub.epoch_of(TGT, "gbdt"),
            "actions": [dict(a) for a in ap.actions],
            "status": ap.status(),
            "kinds": [e["kind"] for e in ap.audit.events()],
            "audit": ap.audit.json_text(),
            "spans": json.dumps(obs.tracer.export(), sort_keys=True),
            "timeline": tl.json_text(),
            "peak_score": max(v for _, v in tl.series("drift_score")),
            "final_score": obs.drift.score(),
            "hub": hub, "obs": obs, "ap": ap,
        }

    @pytest.fixture(scope="class")
    def runs(self):
        return self.run_once(), self.run_once()

    def test_drift_fires_and_autopilot_rolls_over(self, runs):
        a = runs[0]
        assert a["epoch1"] > a["epoch0"]                 # bank rolled over
        (act,) = a["actions"]                            # exactly one action
        assert act["setting"] == TGT_KEY
        assert 0 < act["n_measurements"] <= 64           # budget respected
        assert act["focus_op_types"]                     # targeted, not blind
        assert a["status"]["actions"] == 1
        assert a["status"]["suppressed"] == 0

    def test_drift_returns_below_threshold(self, runs):
        a = runs[0]
        assert a["peak_score"] > 1.0                     # drift was real
        assert a["final_score"] < 1.0                    # recal fixed it
        # Residual shrink: the post-rollover mean bias at the worst cell
        # is far below the injected warp's log(2.4).
        worst = a["obs"].drift.worst_cells(1)
        assert worst and abs(worst[0]["mean"]) < 0.5

    def test_sequence_reconstructable_from_audit(self, runs):
        kinds = runs[0]["kinds"]
        order = ["alert.fire", "autopilot.plan", "autopilot.recalibrate",
                 "autopilot.rollover", "autopilot.drift_reset", "alert.clear"]
        idx = [kinds.index(k) for k in order]            # each present once
        assert idx == sorted(idx)
        assert all(kinds.count(k) == 1 for k in order)
        # The fire's trace id threads through to the autopilot span tree.
        (fire,) = [e for e in runs[0]["ap"].audit.events("alert.fire")]
        spans = json.loads(runs[0]["spans"])
        action = next(s for s in spans if s["name"] == "autopilot.action")
        assert action["tid"] == fire["tid"]
        names = {s["name"] for s in spans
                 if s["tid"] == fire["tid"]}
        assert {"alert.fire", "autopilot.action", "autopilot.recalibrate",
                "autopilot.rollover"} <= names

    def test_bit_identical_replay(self, runs):
        a, b = runs
        assert a["audit"] == b["audit"]                  # byte-equal log
        assert a["spans"] == b["spans"]                  # byte-equal spans
        assert a["timeline"] == b["timeline"]            # byte-equal ring

    def test_action_error_is_audited_not_raised(self):
        """An action that blows up (here: no source bank) must be
        swallowed, audited, and dumped — never thrown into whatever
        thread was driving `step()`."""
        clock = ManualClock()
        obs = Observability(clock=clock, seed=2, drift_min_count=1)
        hub = PredictorHub()
        tl = MetricsTimeline(clock=clock, interval=1)
        tl.track("drift_score", obs.drift.score)
        eng = AlertEngine(tl, [AlertRule("drift", series="drift_score",
                                         threshold=1.0, clear_threshold=0.1)],
                          obs=obs)
        store = ProfileStore()
        ap = RecalibrationAutopilot(
            obs, eng, hub, store, SRC,
            config=AutopilotConfig(cooldown=100.0),
            rollout=lambda *_a: 1)
        calls = []
        ap.register_device(TGT, lambda: calls.append(1))
        obs.drift.observe(TGT_KEY, "conv2d", 0.01, 0.05)
        clock.advance(1)
        ap.step()                                        # fire #1 -> error
        # (no source bank: the action errors, which must be audited and
        # swallowed, never raised into the stepping thread)
        assert ap.audit.events("autopilot.error")
        assert not calls


# ---------------------------------------------------------------------------
# Mid-flood rollover over TCP: nothing lost, nothing double-answered
# ---------------------------------------------------------------------------

class TestMidFloodRollover:
    THREADS, PER = 8, 6

    def test_rollover_mid_flood_conserves_requests(self):
        clock = ManualClock()
        obs = Observability(clock=clock, seed=9, drift_threshold=0.5,
                            drift_min_count=4)
        store, graphs, hub = build_fleet()
        svc = LatencyService(hub, default_setting=SRC, predictor="gbdt",
                             obs=obs)
        tl = MetricsTimeline(clock=clock, interval=1, capacity=256)
        tl.track("drift_score", obs.drift.score)
        eng = AlertEngine(tl, [AlertRule("drift", series="drift_score",
                                         threshold=1.0, sustain=3)], obs=obs)
        drifted = DEVICE.warp_shift(scale=2.4, seed_offset=3)
        ap = RecalibrationAutopilot(
            obs, eng, hub, store, SRC,
            config=AutopilotConfig(budget_k=48, cooldown=4.0, seed=0))
        ap.register_device(
            TGT, lambda: ReplayProfileSession(store, drifted, SRC))
        epoch0 = hub.epoch_of(TGT, "gbdt")

        server = LatencyRPCServer(
            svc, obs=obs, autopilot=ap,
            policy=BatchPolicy(max_batch=8, max_wait_ticks=5,
                               max_queue=1024))
        host, port = server.start()
        n = self.THREADS * self.PER
        errs, epochs_seen = [], set()

        def worker(t):
            try:
                with LatencyClient(host, port, timeout=30.0) as c:
                    for i in range(self.PER):
                        rep = c.predict_e2e(graphs[(t + i) % len(graphs)],
                                            TGT)
                        epochs_seen.add(rep.bank_epoch)
                        assert rep.e2e_s > 0
                    assert c.retries == 0
            except Exception as exc:                     # surfaced post-join
                errs.append(exc)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(self.THREADS)]
        for t in threads:
            t.start()
        # Drive the control loop from this thread while the flood runs:
        # drift in, alert fires, recalibration + rollover land mid-flight.
        while any(t.is_alive() for t in threads):
            observe_round(store, svc, obs, drifted)
            clock.advance(1)
            ap.step()
        for t in threads:
            t.join()
        assert not errs, errs
        # Keep stepping until the loop has actually actuated (the flood
        # may outpace three sustain ticks on a fast box).
        for _ in range(12):
            if ap.actions:
                break
            observe_round(store, svc, obs, drifted)
            clock.advance(1)
            ap.step()

        try:
            with LatencyClient(host, port, timeout=30.0) as probe:
                snap = probe.metrics()["snapshot"]
                out = probe.metrics(timeline=True, audit=True)
                health = probe.health()
        finally:
            server.stop()

        # Closed loop actually closed: epoch advanced, drift back down.
        assert len(ap.actions) >= 1
        epoch1 = hub.epoch_of(TGT, "gbdt")
        assert epoch1 > epoch0
        assert obs.drift.score() < 1.0
        assert all(epoch0 <= e <= epoch1 for e in epochs_seen)

        # Conservation across the swap: nothing lost, nothing doubled.
        c = snap["counters"]
        submitted = sum(c["rpc_batcher_submitted_total"].values())
        answered = sum(c["rpc_batcher_answered_total"].values())
        assert submitted == n
        assert answered == n
        assert sum(c.get("rpc_batcher_failed_total", {}).values()) == 0
        assert sum(c.get("rpc_batcher_rejected_total", {}).values()) == 0
        assert sum(c["autopilot_actions_total"].values()) == len(ap.actions)

        # The new RPC surfaces: timeline ring + audit log + health status.
        assert out["timeline"]["samples"] == tl.samples
        kinds = [e["kind"] for e in out["audit"]]
        assert "autopilot.rollover" in kinds
        assert [e["kind"] for e in
                probe_audit_filter(out["audit"], "alert.fire")]
        assert health["autopilot"]["actions"] == len(ap.actions)
        assert health["metrics"]["drift_top"] is None or \
            health["metrics"]["drift_top"]["setting"] == TGT_KEY
        assert "autopilot" in snap["collected"]
        assert snap["collected"]["alerts"]["consumed"] == tl.samples

    def test_metrics_timeline_requires_autopilot(self):
        srv = LatencyRPCServer(
            LatencyService(PredictorHub(), default_setting=SRC),
            obs=Observability(), auto_start_batcher=False)
        with pytest.raises(RPCError):
            srv._metrics({"timeline": True})
        with pytest.raises(RPCError):
            srv._metrics({"audit": True})


def probe_audit_filter(events, kind):
    return [e for e in events if e["kind"] == kind]

"""Core IR, kernel-fusion (Alg. C.1) and kernel-selection (Alg. C.2) tests."""
import numpy as np
import pytest

from repro.core.ir import OpGraph, op_signature
from repro.core.fusion import fuse_graph, is_linkable
from repro.core.selection import (
    apply_selection, check_grouped_conv2d, check_winograd, get_device,
    select_conv_kernel,
)


def simple_graph():
    g = OpGraph("t")
    x0 = g.add_input((1, 8, 8, 16))
    (c1,) = g.add_op("conv2d", [x0], [(1, 8, 8, 16)],
                     {"kernel_h": 3, "kernel_w": 3, "stride": 1, "groups": 1})
    (e1,) = g.add_op("elementwise", [c1], [(1, 8, 8, 16)], {"ew_kind": "sqrt"})
    (a1,) = g.add_op("elementwise", [e1, x0], [(1, 8, 8, 16)], {"ew_kind": "add"})
    (m1,) = g.add_op("mean", [a1], [(1, 16)])
    (f1,) = g.add_op("fully_connected", [m1], [(1, 10)])
    g.mark_output(f1)
    g.validate()
    return g


class TestIR:
    def test_validate_rejects_bad_order(self):
        g = OpGraph("bad")
        x0 = g.add_input((1, 4, 4, 3))
        phantom = g.add_tensor((1, 4, 4, 3))
        g.add_op("elementwise", [phantom], [(1, 4, 4, 3)], {"ew_kind": "abs"})
        with pytest.raises(ValueError):
            g.validate()

    def test_roundtrip_json(self):
        g = simple_graph()
        g2 = OpGraph.from_json(g.to_json())
        assert g2.fingerprint() == g.fingerprint()
        assert g2.op_type_counts() == g.op_type_counts()

    def test_signature_stable_and_distinct(self):
        g = simple_graph()
        sigs = [op_signature(g, n) for n in g.nodes]
        assert len(set(sigs)) == len(sigs)  # all configs distinct here
        g2 = OpGraph.from_json(g.to_json())
        assert [op_signature(g2, n) for n in g2.nodes] == sigs


class TestFusion:
    def test_elementwise_chain_merges(self):
        g = simple_graph()
        groups, fused = fuse_graph(g)
        # conv ← sqrt ← add merged (add uses conv-chain output as 1st input).
        assert len(groups) == 3
        conv = fused.nodes[0]
        assert conv.op_type == "conv2d"
        assert conv.fused == ("sqrt", "add")
        # the add's residual operand is rewired onto the conv node
        assert len(conv.inputs) == 2

    def test_multi_consumer_blocks_fusion(self):
        g = OpGraph("t")
        x0 = g.add_input((1, 4, 4, 8))
        (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, 8)],
                         {"kernel_h": 1, "kernel_w": 1, "stride": 1, "groups": 1})
        (e1,) = g.add_op("elementwise", [c1], [(1, 4, 4, 8)], {"ew_kind": "abs"})
        (e2,) = g.add_op("elementwise", [c1], [(1, 4, 4, 8)], {"ew_kind": "neg"})
        (a1,) = g.add_op("elementwise", [e1, e2], [(1, 4, 4, 8)], {"ew_kind": "add"})
        g.mark_output(a1)
        groups, _ = fuse_graph(g)
        # conv has 2 consumers → not fused.  abs feeds add as 1st input
        # but add's OTHER operand (neg) is produced later → the
        # execution-order extension blocks that merge too → 4 kernels.
        assert len(groups) == 4

    def test_second_input_position_blocks_fusion(self):
        # Paper L14: candidate must use tensor as its FIRST input.
        g = OpGraph("t")
        x0 = g.add_input((1, 4, 4, 8))
        (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, 8)],
                         {"kernel_h": 1, "kernel_w": 1, "stride": 1, "groups": 1})
        (a1,) = g.add_op("elementwise", [x0, c1], [(1, 4, 4, 8)], {"ew_kind": "add"})
        g.mark_output(a1)
        groups, _ = fuse_graph(g)
        assert len(groups) == 2  # no merge: c1 is add's SECOND input

    def test_graph_output_not_fused(self):
        g = OpGraph("t")
        x0 = g.add_input((1, 4, 4, 8))
        (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, 8)],
                         {"kernel_h": 1, "kernel_w": 1, "stride": 1, "groups": 1})
        g.mark_output(c1)
        (e1,) = g.add_op("elementwise", [c1], [(1, 4, 4, 8)], {"ew_kind": "abs"})
        g.mark_output(e1)
        groups, _ = fuse_graph(g)
        assert len(groups) == 2


class TestDiamondFusion:
    """Regression: fan-out > 1 must not block fusion when every consumer
    edge lands on the SAME node (diamond collapse via the "@self"
    duplicate-operand convention)."""

    @staticmethod
    def _diamond():
        # x → conv → sqrt → add(sqrt_out, conv_out): after sqrt merges
        # into add, conv's output feeds one node through TWO edges.
        g = OpGraph("diamond")
        x0 = g.add_input((1, 4, 4, 8))
        (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, 8)],
                         {"kernel_h": 1, "kernel_w": 1, "stride": 1,
                          "groups": 1})
        (s1,) = g.add_op("elementwise", [c1], [(1, 4, 4, 8)],
                         {"ew_kind": "sqrt"})
        (a1,) = g.add_op("elementwise", [s1, c1], [(1, 4, 4, 8)],
                         {"ew_kind": "add"})
        g.mark_output(a1)
        g.validate()
        return g

    def test_diamond_collapses_to_single_kernel(self):
        groups, fused = fuse_graph(self._diamond())
        assert len(groups) == 1
        node = fused.nodes[0]
        assert node.op_type == "conv2d"
        assert node.fused == ("sqrt", "add@self")
        assert node.inputs == (0,)       # residual edge folded away
        fused.validate()
        # Idempotent: re-fusing the collapsed graph changes nothing.
        _, again = fuse_graph(fused)
        assert [n.fused for n in again.nodes] == [n.fused for n in fused.nodes]
        assert [n.inputs for n in again.nodes] == \
            [n.inputs for n in fused.nodes]

    def test_diamond_execution_parity(self):
        from repro.core.executor import GraphExecutor
        g = self._diamond()
        _, fused = fuse_graph(g)
        ex = GraphExecutor(g, "op_by_op")
        ex_f = GraphExecutor(fused, "op_by_op")
        x = ex.example_inputs()
        np.testing.assert_allclose(np.asarray(ex(*x)[0]),
                                   np.asarray(ex_f(*x)[0]),
                                   rtol=1e-6, atol=1e-6)

    def test_duplicate_operand_binop_merges(self):
        # add(c, c): both operands are the producer's output directly.
        g = OpGraph("dup")
        x0 = g.add_input((1, 4, 4, 8))
        (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, 8)],
                         {"kernel_h": 1, "kernel_w": 1, "stride": 1,
                          "groups": 1})
        (a1,) = g.add_op("elementwise", [c1, c1], [(1, 4, 4, 8)],
                         {"ew_kind": "add"})
        g.mark_output(a1)
        groups, fused = fuse_graph(g)
        assert len(groups) == 1
        assert fused.nodes[0].fused == ("add@self",)
        assert fused.nodes[0].inputs == (0,)

    def test_distinct_consumer_nodes_still_block(self):
        # Two different consumer NODES (not edges) must keep blocking —
        # the diamond fix dedupes edges per node, nothing more.
        g = OpGraph("fan")
        x0 = g.add_input((1, 4, 4, 8))
        (c1,) = g.add_op("conv2d", [x0], [(1, 4, 4, 8)],
                         {"kernel_h": 1, "kernel_w": 1, "stride": 1,
                          "groups": 1})
        (e1,) = g.add_op("elementwise", [c1], [(1, 4, 4, 8)],
                         {"ew_kind": "abs"})
        g.mark_output(e1)
        (e2,) = g.add_op("elementwise", [c1], [(1, 4, 4, 8)],
                         {"ew_kind": "neg"})
        g.mark_output(e2)
        groups, _ = fuse_graph(g)
        assert len(groups) == 3


class TestSelection:
    def _conv(self, in_c, out_c, hw, k=3, stride=1, groups=1):
        g = OpGraph("t")
        x0 = g.add_input((1, hw, hw, in_c))
        (c1,) = g.add_op("conv2d", [x0], [(1, hw // stride, hw // stride, out_c)],
                         {"kernel_h": k, "kernel_w": k, "stride": stride,
                          "groups": groups})
        g.mark_output(c1)
        return g, g.nodes[0]

    def test_paper_table2_row1(self):
        # 64ch, 56x56: src/dst_depth=16 — No on Adreno, Yes on Mali.
        g, node = self._conv(64, 64, 56)
        assert not check_winograd(get_device("adreno640"), node, g)
        assert check_winograd(get_device("mali_g76"), node, g)
        assert check_winograd(get_device("powervr_ge8320"), node, g)

    def test_paper_table2_row2(self):
        # 128ch, 28x28: tiles=49 — too small for Adreno6xx, fine for Mali.
        g, node = self._conv(128, 128, 28)
        assert not check_winograd(get_device("adreno640"), node, g)
        assert check_winograd(get_device("mali_g76"), node, g)

    def test_paper_table2_row3(self):
        # 256ch, 14x14: tiles=16 < 32 — No everywhere.
        g, node = self._conv(256, 256, 14)
        assert not check_winograd(get_device("adreno640"), node, g)
        assert not check_winograd(get_device("mali_g76"), node, g)

    def test_winograd_requires_3x3_stride1(self):
        g, node = self._conv(64, 64, 56, k=5)
        assert not check_winograd(get_device("mali_g76"), node, g)
        g, node = self._conv(64, 64, 56, k=3, stride=2)
        assert not check_winograd(get_device("mali_g76"), node, g)

    def test_grouped_conv_selection(self):
        g, node = self._conv(64, 64, 28, k=3, groups=4)
        assert check_grouped_conv2d(get_device("mali_g76"), node, g)
        assert select_conv_kernel(get_device("mali_g76"), node, g) == "grouped_conv2d"

    def test_apply_selection_rewrites(self):
        g, _ = self._conv(64, 64, 56)
        out = apply_selection(g, get_device("mali_g76"))
        assert out.nodes[0].op_type == "winograd_conv2d"
        out = apply_selection(g, get_device("adreno640"))
        assert out.nodes[0].op_type == "conv2d"

    def test_tpu_selection(self):
        g, node = self._conv(128, 128, 64)
        assert select_conv_kernel(get_device("tpu_v5e"), node, g) == "winograd_conv2d"
        g, node = self._conv(32, 32, 64)   # channels too small for MXU
        assert select_conv_kernel(get_device("tpu_v5e"), node, g) == "conv2d"

"""Per-architecture smoke tests: REDUCED config of the same family,
one forward/train step + one decode step on CPU; shapes + no NaNs.
(The FULL configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros((b, cfg.vision_seq, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    logits = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = 2
    cache = model.init_cache(b, 64)
    batch = {"token": jnp.ones((b, 1), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros((b, cfg.vision_seq, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "encdec":
        batch["memory"] = jnp.zeros((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, batch, cache)
    # feed a DIFFERENT token: with identical tokens V is constant so the
    # attention output is v for any weights and logits repeat exactly.
    batch2 = dict(batch, token=jnp.full((b, 1), 7, jnp.int32))
    logits2, cache = step(params, batch2, cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert not np.allclose(np.asarray(logits), np.asarray(logits2))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_gradients_flow(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, b=1, s=16)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(n) for n in norms), f"{arch}: non-finite grads"
    assert sum(norms) > 0, f"{arch}: zero gradients"

"""Fault-tolerance suite: chaos injection, retry/backoff, shedding tiers,
and zero-downtime bank rollover.

Everything here is deterministic-by-construction: fault schedules are
pure functions of a `FaultPlan` seed (replayable bit-identically),
retry backoff traces are asserted against the policy's closed-form
schedule with injected sleep/clock (no wall-clock sleeps), and the
shedding-tier state machine runs under a `ManualClock`.  The only
wall-clock pieces are the socket end-to-end scenarios (reconnect,
transport drops, rollover under flood), which assert *outcomes* —
every request answered exactly once, correct epoch attribution — not
timings.

``RPC_CHAOS_ITERS`` scales the iteration counts (CI smoke profile sets
it low; the default is a fuller local run).
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.dataset import synthetic_graphs
from repro.core.nas_space import NASSpaceConfig, sample_architecture
from repro.core.profiler import DeviceSetting
from repro.pipeline import LatencyService, PredictorHub, ProfileStore
from repro.rpc import protocol
from repro.rpc.batcher import BatchPolicy, ManualClock, MicroBatcher
from repro.rpc.chaos import FaultPlan, FaultSpec
from repro.rpc.client import LatencyClient
from repro.rpc.protocol import RPCError
from repro.rpc.resilience import CircuitBreaker, RetryPolicy, retry_call
from repro.rpc.server import LatencyRPCServer
from repro.transfer import CostModelProfileSession

ITERS = int(os.environ.get("RPC_CHAOS_ITERS", "20"))
SOURCE = DeviceSetting("cpu_f32", "float32", "op_by_op")
SPACE = NASSpaceConfig(resolution=16)


def graphs_for(seeds):
    return [sample_architecture(s, SPACE) for s in seeds]


@pytest.fixture(scope="module")
def served():
    """Cost-model-profiled store + trained hub + service (same recipe
    as tests/test_rpc.py, independent instance so chaos cannot leak)."""
    store = ProfileStore()
    session = CostModelProfileSession(store=store, seed=3)
    for g in synthetic_graphs(8, resolution=16):
        session.profile_graph(g, SOURCE)
    hub = PredictorHub()
    hub.train(store, SOURCE, "gbdt", hparams={"n_stages": 20}, min_samples=3)
    svc = LatencyService(hub, default_setting=SOURCE, predictor="gbdt")
    return {"store": store, "hub": hub, "service": svc}


def make_bank(store, *, seed=0, n_stages=10):
    """An independently trained gbdt bank (distinct hparams → distinct
    predictions) to roll over onto a serving hub."""
    h = PredictorHub()
    return h.train(store, SOURCE, "gbdt", hparams={"n_stages": n_stages},
                   min_samples=3, seed=seed, save=False)


def ref_service(bank):
    """A fresh service whose ONLY bank is ``bank`` — the per-epoch
    reference oracle for rollover attribution checks."""
    h = PredictorHub()
    h.register(SOURCE, "gbdt", bank)
    return LatencyService(h, default_setting=SOURCE, predictor="gbdt")


# ---------------------------------------------------------------------------
# FaultPlan: pure, seeded, replayable
# ---------------------------------------------------------------------------

class TestFaultPlanDeterminism:
    SPECS = (FaultSpec(site="flush", kind="error", rate=0.25),
             FaultSpec(site="flush", kind="wedge", rate=0.15),
             FaultSpec(site="dispatch", kind="delay", rate=0.3,
                       delay_s=0.001),
             FaultSpec(site="transport", kind="drop", rate=0.2))

    def test_schedule_matches_consumed_decisions(self):
        n = max(ITERS, 50)
        for site in ("flush", "dispatch", "transport"):
            plan = FaultPlan(11, self.SPECS)
            preview = plan.schedule(site, n)
            consumed = [(f.kind if f else None)
                        for f in (plan.decide(site) for _ in range(n))]
            assert preview == consumed
            assert plan.events(site) == n

    def test_same_seed_bit_identical_different_seed_not(self):
        n = max(ITERS, 200)
        a = FaultPlan(42, self.SPECS).schedule("flush", n)
        b = FaultPlan(42, self.SPECS).schedule("flush", n)
        c = FaultPlan(43, self.SPECS).schedule("flush", n)
        assert a == b                       # bit-identical replay
        assert a != c                       # the seed actually matters
        assert any(k is not None for k in a)
        assert any(k is None for k in a)

    def test_injected_tally_matches_schedule(self):
        n = max(ITERS, 100)
        plan = FaultPlan(7, self.SPECS)
        sched = plan.schedule("flush", n)
        for _ in range(n):
            plan.decide("flush")
        inj = plan.injected()
        assert inj.get("flush/error", 0) == sched.count("error")
        assert inj.get("flush/wedge", 0) == sched.count("wedge")
        assert plan.stats()["events"]["flush"] == n

    def test_rates_zero_and_one(self):
        never = FaultPlan(1, [FaultSpec(site="flush", kind="error",
                                        rate=0.0)])
        always = FaultPlan(1, [FaultSpec(site="flush", kind="wedge",
                                         rate=1.0)])
        assert never.schedule("flush", 50) == [None] * 50
        assert always.schedule("flush", 50) == ["wedge"] * 50

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(site="flush", kind="meteor", rate=0.5)
        with pytest.raises(ValueError):
            FaultSpec(site="flush", kind="error", rate=1.5)
        with pytest.raises(ValueError):
            FaultSpec(site="flush", kind="delay", rate=0.1, delay_s=-1)

    def test_threaded_decide_consumes_each_index_once(self):
        plan = FaultPlan(5, self.SPECS)
        n, threads = 200, 8
        out = []
        lock = threading.Lock()

        def worker():
            for _ in range(n // threads):
                f = plan.decide("flush")
                with lock:
                    out.append(f.kind if f else None)

        ts = [threading.Thread(target=worker) for _ in range(threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        # Interleaving may permute arrival order, but the multiset of
        # decisions is exactly the schedule's first n entries.
        assert sorted(out, key=str) == \
            sorted(plan.schedule("flush", n), key=str)


# ---------------------------------------------------------------------------
# RetryPolicy / retry_call: deterministic backoff, budgets, breaker
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


class TestRetryPolicy:
    def test_schedule_deterministic_and_capped(self):
        pol = RetryPolicy(max_attempts=8, base_delay_s=0.1, multiplier=2.0,
                          max_delay_s=0.5, jitter=0.25, seed=9)
        s1, s2 = pol.backoff_schedule(), pol.backoff_schedule()
        assert s1 == s2 and len(s1) == 7
        for k, d in enumerate(s1):
            base = min(0.1 * 2.0 ** k, 0.5)
            assert base * 0.75 <= d <= base * 1.25   # jitter bounds
        assert pol.backoff_schedule(seed=10) != s1   # seed matters

    def test_retry_only_retryable_and_exact_backoff_trace(self):
        pol = RetryPolicy(max_attempts=5, base_delay_s=0.05, seed=3,
                          deadline_s=100.0)
        clock = FakeClock()
        fails = [3]                # first 3 attempts fail retryably
        slept = []

        def attempt(budget):
            assert budget > 0
            if fails[0] > 0:
                fails[0] -= 1
                raise RPCError(protocol.E_OVERLOADED, "shed")
            return "done"

        out = retry_call(attempt, pol, sleep=slept.append, clock=clock)
        assert out == "done"
        assert slept == pol.backoff_schedule()[:3]   # exact, closed form

        def fatal(budget):
            raise RPCError(protocol.E_BAD_REQUEST, "no", retryable=False)

        slept.clear()
        with pytest.raises(RPCError) as ei:
            retry_call(fatal, pol, sleep=slept.append, clock=clock)
        assert ei.value.code == protocol.E_BAD_REQUEST
        assert slept == []                           # no retry attempted

    def test_deadline_budget_exhausts_with_typed_timeout(self):
        pol = RetryPolicy(max_attempts=100, base_delay_s=1.0, multiplier=1.0,
                          jitter=0.0, deadline_s=3.5, seed=0)
        clock = FakeClock()

        def always(budget):
            raise RPCError(protocol.E_UNAVAILABLE, "down")

        with pytest.raises(RPCError) as ei:
            retry_call(always, pol, sleep=clock.sleep, clock=clock)
        assert ei.value.code == protocol.E_TIMEOUT
        assert "deadline exhausted" in ei.value.message
        assert clock.t <= 3.5 + 1e-9     # sleeps never overshoot the budget

    def test_max_attempts_surfaces_last_error(self):
        pol = RetryPolicy(max_attempts=3, base_delay_s=0.0, jitter=0.0,
                          deadline_s=100.0)
        clock = FakeClock()
        calls = [0]

        def always(budget):
            calls[0] += 1
            raise RPCError(protocol.E_OVERLOADED, f"attempt {calls[0]}")

        with pytest.raises(RPCError) as ei:
            retry_call(always, pol, sleep=clock.sleep, clock=clock)
        assert calls[0] == 3
        assert ei.value.message == "attempt 3"

    def test_circuit_breaker_opens_halfopens_closes(self):
        clock = FakeClock()
        br = CircuitBreaker(failure_threshold=3, reset_after_s=2.0,
                            clock=clock)
        assert br.state() == br.CLOSED and br.allow()
        for _ in range(3):
            br.record_failure()
        assert br.state() == br.OPEN and not br.allow()
        clock.t += 2.0
        assert br.state() == br.HALF_OPEN
        assert br.allow()                  # the single probe
        assert not br.allow()              # second caller blocked
        br.record_success()
        assert br.state() == br.CLOSED and br.allow()
        # Failed probe re-opens immediately.
        for _ in range(3):
            br.record_failure()
        clock.t += 2.0
        assert br.allow()
        br.record_failure()
        assert br.state() == br.OPEN and br.opens == 2

    def test_retry_call_respects_open_breaker(self):
        clock = FakeClock()
        br = CircuitBreaker(failure_threshold=1, reset_after_s=10.0,
                            clock=clock)
        br.record_failure()
        pol = RetryPolicy(deadline_s=100.0)
        with pytest.raises(RPCError) as ei:
            retry_call(lambda b: "x", pol, sleep=clock.sleep, clock=clock,
                       breaker=br)
        assert ei.value.code == protocol.E_UNAVAILABLE
        assert "circuit breaker open" in ei.value.message


# ---------------------------------------------------------------------------
# Shedding tiers (ManualClock state machine)
# ---------------------------------------------------------------------------

class ShedStub:
    """Minimal service for tier tests: everything fresh unless cached."""

    def __init__(self):
        self.default_setting = SOURCE
        self.predictor = "gbdt"
        self.cached = set()

    def cache_peek(self, graph, setting, family):
        return ("cached", graph) if graph in self.cached else None

    def predict_batch(self, graphs, setting, family):
        return [("fresh", g) for g in graphs]


class TestSheddingTiers:
    def mk(self, **kw):
        svc = ShedStub()
        clock = ManualClock()
        policy = BatchPolicy(**{"max_batch": 32, "max_wait_ticks": 1,
                                "max_queue": 10, "shed_frac": 0.5,
                                "shed_reject_ticks": 2, **kw})
        return svc, clock, MicroBatcher(svc, policy, clock=clock,
                                        auto_start=False)

    def test_accept_to_cache_only_watermark(self):
        svc, clock, b = self.mk()
        futs = [b.submit(f"g{i}") for i in range(5)]   # fill to 5 = 0.5*10
        assert b.shed_tier() == "cache_only"
        with pytest.raises(RPCError) as ei:
            b.submit("fresh_over")                     # fresh work shed
        assert ei.value.code == protocol.E_OVERLOADED and ei.value.retryable
        assert "cache_only" in ei.value.message
        svc.cached.add("hot")
        hit = b.submit("hot")                          # cache hits survive
        assert hit.done() and hit.result(0) == ("cached", "hot")
        st = b.stats()
        assert st["shed_cache_only"] == 1 and st["shed_rejected"] == 0
        clock.advance(1)
        assert b.run_pending() == 5                    # drain...
        assert b.shed_tier() == "accept"               # ...recovers the tier
        assert all(f.result(0)[0] == "fresh" for f in futs)

    def test_reject_tier_when_queue_stuck(self):
        svc, clock, b = self.mk()
        for i in range(5):
            b.submit(f"s{i}")
        assert b.shed_tier() == "cache_only"
        # Head deadline = 1; overdue age must EXCEED shed_reject_ticks=2.
        clock.advance(3)                 # now=3, overdue by 2: not yet
        assert b.shed_tier() == "cache_only"
        clock.advance(1)                 # now=4, overdue by 3 > 2: stuck
        assert b.shed_tier() == "reject"
        svc.cached.add("hot")
        with pytest.raises(RPCError) as ei:
            b.submit("hot")              # reject shuts even the cache path
        assert ei.value.code == protocol.E_OVERLOADED
        assert "reject" in ei.value.message
        assert b.stats()["shed_rejected"] == 1
        assert b.run_pending() == 5      # flushing unsticks the queue
        assert b.shed_tier() == "accept"
        assert b.submit("after").done() is False       # admitted again

    def test_below_watermark_accepts(self):
        svc, clock, b = self.mk()
        for i in range(4):               # 4 < 5 = watermark
            b.submit(f"a{i}")
        assert b.shed_tier() == "accept"
        b.submit("fifth_ok")             # the submit that CROSSES is fine
        assert b.queued() == 5

    def test_legacy_defaults_single_cliff(self):
        """shed_frac=1.0 + no reject ticks == the original behavior:
        fresh rejected only at a full queue, cache served always."""
        svc, clock, b = self.mk(shed_frac=1.0, shed_reject_ticks=None,
                                max_queue=3)
        for i in range(3):
            b.submit(f"x{i}")
        assert b.shed_tier() == "cache_only"
        with pytest.raises(RPCError):
            b.submit("over")
        clock.advance(100)               # stuck forever, still never reject
        assert b.shed_tier() == "cache_only"
        svc.cached.add("hot")
        assert b.submit("hot").done()


# ---------------------------------------------------------------------------
# Chaos in the batcher: exactly-once under error/wedge/delay storms
# ---------------------------------------------------------------------------

class TestBatcherChaosExactlyOnce:
    def test_seeded_storm_every_request_settles_once(self):
        plan = FaultPlan(13, [
            FaultSpec(site="flush", kind="error", rate=0.2,
                      code=protocol.E_UNAVAILABLE, message="injected"),
            FaultSpec(site="flush", kind="wedge", rate=0.2),
        ])
        svc = ShedStub()
        clock = ManualClock()
        b = MicroBatcher(svc, BatchPolicy(max_batch=4, max_wait_ticks=1,
                                          max_queue=4096),
                         clock=clock, auto_start=False, chaos=plan)
        n = max(4 * ITERS, 40)
        futs = [b.submit(f"g{i}") for i in range(n)]
        for _ in range(20 * n):          # bounded pumping, no sleeps
            clock.advance(1)
            b.run_pending()
            if all(f.done() for f in futs):
                break
        assert all(f.done() for f in futs), "requests lost under chaos"
        ok = err = 0
        for i, f in enumerate(futs):
            e = f.error()
            if e is None:
                assert f.result(0) == ("fresh", f"g{i}")   # not cross-wired
                ok += 1
            else:
                assert e.code == protocol.E_UNAVAILABLE
                assert e.message == "injected"
                err += 1
        assert ok + err == n
        st = b.stats()
        assert st["answered"] == ok and st["failed"] == err
        inj = plan.injected()
        assert st["wedged_flushes"] == inj.get("flush/wedge", 0)
        if inj.get("flush/error"):
            assert err > 0
        b.close()

    def test_replay_same_seed_same_outcome_split(self):
        def run(seed):
            plan = FaultPlan(seed, [FaultSpec(site="flush", kind="error",
                                              rate=0.3)])
            svc = ShedStub()
            clock = ManualClock()
            b = MicroBatcher(svc, BatchPolicy(max_batch=2, max_wait_ticks=0,
                                              max_queue=4096),
                             clock=clock, auto_start=False, chaos=plan)
            futs = [b.submit(f"r{i}") for i in range(30)]
            b.flush_all()
            return [f.error().code if f.error() else "ok" for f in futs]

        assert run(21) == run(21)
        assert run(21) != run(22)

    def test_wedge_storm_drains_or_fails_typed_on_close(self):
        """A rate-1.0 wedge plan can never flush; close() must not hang
        and must fail the stranded requests with a typed envelope."""
        plan = FaultPlan(1, [FaultSpec(site="flush", kind="wedge", rate=1.0)])
        svc = ShedStub()
        clock = ManualClock()
        b = MicroBatcher(svc, BatchPolicy(max_batch=4, max_wait_ticks=0,
                                          max_queue=64),
                         clock=clock, auto_start=False, chaos=plan)
        futs = [b.submit(f"w{i}") for i in range(8)]
        assert b.run_pending() == 0          # all wedged, no progress
        assert b.queued() == 8               # requeued, nothing lost
        b.close()
        for f in futs:
            assert f.done()
            assert f.error().code == protocol.E_UNAVAILABLE
        assert b.stats()["failed"] == 8


# ---------------------------------------------------------------------------
# Client retry vs dispatch chaos: schedule asserted in closed form
# ---------------------------------------------------------------------------

class TestClientRetryConvergence:
    def test_retries_converge_with_exact_backoff_trace(self, served):
        seed = 97
        plan = FaultPlan(seed, [FaultSpec(site="dispatch", kind="error",
                                          rate=0.4,
                                          code=protocol.E_UNAVAILABLE,
                                          message="chaos says no")])
        pol = RetryPolicy(max_attempts=10, base_delay_s=0.01, seed=5,
                          deadline_s=60.0)
        n_calls = max(ITERS // 2, 8)
        # Closed-form expectation: walk the dispatch schedule, one event
        # per attempt, sequential single-threaded calls.
        sched = plan.schedule("dispatch", 50 * n_calls)
        expected_sleeps, i = [], 0
        for _ in range(n_calls):
            fails = 0
            while sched[i] == "error":
                i += 1
                fails += 1
            i += 1                        # the clean attempt
            assert fails < pol.max_attempts, "pick a friendlier seed"
            expected_sleeps += pol.backoff_schedule()[:fails]
        server = LatencyRPCServer(served["service"], chaos=plan)
        host, port = server.start()
        slept = []
        cli = LatencyClient(host, port, timeout=30.0, retry=pol,
                            sleep=slept.append)
        try:
            for _ in range(n_calls):
                banks = cli.call("available", {})
                assert ["float32/op_by_op", "gbdt"] in banks["banks"]
        finally:
            cli.close()
            server.stop()
        assert slept == expected_sleeps   # the exact seeded backoff trace
        assert cli.retries == len(expected_sleeps)
        assert plan.events("dispatch") == i


# ---------------------------------------------------------------------------
# Transport drops end-to-end: reconnect + retry reach 100% success
# ---------------------------------------------------------------------------

class TestTransportChaos:
    def test_dropped_connections_heal_to_full_success(self, served):
        plan = FaultPlan(31, [FaultSpec(site="transport", kind="drop",
                                        rate=0.25)])
        server = LatencyRPCServer(
            served["service"], chaos=plan,
            policy=BatchPolicy(max_batch=8, max_wait_ticks=2,
                               max_queue=4096))
        host, port = server.start()
        served["service"].clear_cache()
        pol = RetryPolicy(max_attempts=8, base_delay_s=0.01,
                          max_delay_s=0.05, deadline_s=30.0, seed=2)
        cli = LatencyClient(host, port, timeout=5.0, retry=pol)
        gs = graphs_for(range(700, 700 + max(ITERS, 12)))
        try:
            reports = [cli.predict_e2e(g) for g in gs]
        finally:
            cli.close()
            server.stop()
        assert len(reports) == len(gs)                 # 100% success
        assert [r.fingerprint for r in reports] == \
            [g.fingerprint() for g in gs]
        direct = [served["service"].predict_e2e(g) for g in gs]
        assert [r.e2e_s for r in reports] == [d.e2e_s for d in direct]
        assert plan.injected().get("transport/drop", 0) > 0
        assert cli.reconnects > 0          # drops actually forced reconnects


# ---------------------------------------------------------------------------
# Rollover: health + rollover RPC, epoch attribution, flood survival
# ---------------------------------------------------------------------------

class TestRollover:
    def test_swap_bank_epochs_and_report_attribution(self, served):
        hub, svc = served["hub"], served["service"]
        svc.clear_cache()
        g = graphs_for([800])[0]
        e_old = hub.epoch_of(SOURCE, "gbdt")
        assert e_old >= 1                  # train() stamped it
        before = svc.predict_e2e(g)
        assert before.bank_epoch == e_old
        bank2 = make_bank(served["store"], seed=1, n_stages=5)
        e_new = hub.swap_bank(SOURCE, "gbdt", bank2)
        assert e_new > e_old
        assert hub.epoch_of(SOURCE, "gbdt") == e_new
        after = svc.predict_e2e(g)
        assert after.bank_epoch == e_new
        assert after.e2e_s == ref_service(bank2).predict_e2e(g).e2e_s
        # Reports round-trip the epoch over the wire format.
        from repro.pipeline.service import PredictionReport
        clone = PredictionReport.from_json(after.to_json())
        assert clone.bank_epoch == e_new

    def test_health_and_rollover_rpc_end_to_end(self, served):
        server = LatencyRPCServer(served["service"])
        host, port = server.start()
        served["service"].clear_cache()
        cli = LatencyClient(host, port, timeout=30.0)
        try:
            h = cli.health()
            assert h["status"] == "ok" and h["shed_tier"] == "accept"
            e_before = h["bank_epochs"]["float32/op_by_op"]["gbdt"]
            bank2 = make_bank(served["store"], seed=2, n_stages=7)
            out = cli.rollover(SOURCE, bank2, family="gbdt")
            assert out["setting"] == "float32/op_by_op"
            assert out["family"] == "gbdt"
            assert out["epoch"] > e_before
            h2 = cli.health()
            assert h2["bank_epochs"]["float32/op_by_op"]["gbdt"] == \
                out["epoch"]
            assert h2["hub_epoch"] >= out["epoch"]
            rep = cli.predict_e2e(graphs_for([801])[0])
            assert rep.bank_epoch == out["epoch"]
            assert rep.e2e_s == \
                ref_service(bank2).predict_e2e(graphs_for([801])[0]).e2e_s
        finally:
            cli.close()
            server.stop()

    def test_rollover_under_threaded_flood_loses_nothing(self, served):
        """32 client threads flood predicts while the bank swaps
        mid-flight: every request is answered exactly once, and every
        report's e2e matches the reference oracle for the bank epoch it
        claims to have been computed against."""
        hub, svc = served["hub"], served["service"]
        svc.clear_cache()
        bank_old = hub.get(SOURCE, "gbdt")
        e_old = hub.epoch_of(SOURCE, "gbdt")
        bank_new = make_bank(served["store"], seed=4, n_stages=12)
        server = LatencyRPCServer(
            svc, policy=BatchPolicy(max_batch=16, max_wait_ticks=2,
                                    max_queue=8192))
        host, port = server.start()
        threads_n = 32
        per_thread = max(ITERS // 4, 4)
        total = threads_n * per_thread
        results = []
        errors = []
        done_count = [0]
        lock = threading.Lock()
        start = threading.Barrier(threads_n + 1)

        def worker(tid):
            cli = LatencyClient(host, port, timeout=30.0,
                                retry=RetryPolicy(max_attempts=6,
                                                  base_delay_s=0.01,
                                                  seed=tid))
            try:
                gs = graphs_for(range(1000 + tid * per_thread,
                                      1000 + (tid + 1) * per_thread))
                start.wait()
                for g in gs:
                    rep = cli.predict_e2e(g)
                    with lock:
                        results.append((g, rep))
                        done_count[0] += 1
            except Exception as exc:       # pragma: no cover - fail loudly
                with lock:
                    errors.append(exc)
            finally:
                cli.close()

        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(threads_n)]
        for t in ts:
            t.start()
        start.wait()
        # Swap once the flood is demonstrably in flight but far from
        # done, so both epochs are observable on the answers.
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            with lock:
                if done_count[0] >= max(total // 8, 1):
                    break
            time.sleep(0.001)
        e_new = hub.swap_bank(SOURCE, "gbdt", bank_new)   # mid-flood swap
        for t in ts:
            t.join(timeout=120)
        server.stop()
        assert not errors, errors
        assert len(results) == total       # zero lost, exactly once each
        oracle = {e_old: ref_service(bank_old), e_new: ref_service(bank_new)}
        seen_epochs = set()
        for g, rep in results:
            assert rep.fingerprint == g.fingerprint()     # not cross-wired
            assert rep.bank_epoch in oracle, \
                f"report claims unknown epoch {rep.bank_epoch}"
            seen_epochs.add(rep.bank_epoch)
            want = oracle[rep.bank_epoch].predict_e2e(g).e2e_s
            assert rep.e2e_s == want       # epoch attribution is truthful
        assert e_new in seen_epochs        # the swap actually landed
        assert hub.epoch_of(SOURCE, "gbdt") == e_new

    def test_engine_survives_unavailable_predictor(self, served):
        """ServeEngine degrades (no estimate) instead of crashing when
        the prediction endpoint fails, and refreshes after recovery."""
        from repro.serving import ServeEngine

        class FlakyService:
            def __init__(self, inner):
                self.inner = inner
                self.down = True

            def predict_e2e(self, graph, setting=None):
                if self.down:
                    raise RPCError(protocol.E_UNAVAILABLE, "flood")
                return self.inner.predict_e2e(graph, setting)

        class StubModel:
            def init_cache(self, slots, max_len):
                return {"pos": 0}

            def decode_step(self, params, batch, cache):
                import jax.numpy as jnp
                logits = jnp.tile(jnp.arange(8.0),
                                  (batch["token"].shape[0], 1))
                return logits, {"pos": cache["pos"] + 1}

        flaky = FlakyService(served["service"])
        step = graphs_for([900])[0]
        eng = ServeEngine(StubModel(), params={}, batch_slots=2, max_len=16,
                          latency_service=flaky, step_graph=step,
                          latency_setting=SOURCE)
        assert eng.predicted_step_s is None           # degraded, not dead
        assert eng.stats()["step_bank_epoch"] is None
        eng.submit(np.array([1, 2, 3]), max_new_tokens=2)
        assert len(eng.run(max_steps=10)) == 1        # decode still works
        flaky.down = False
        assert eng.refresh_step_estimate() is not None
        assert eng.predicted_step_s == \
            served["service"].predict_e2e(step, SOURCE).e2e_s
        assert eng.stats()["step_bank_epoch"] == \
            served["hub"].epoch_of(SOURCE, "gbdt")

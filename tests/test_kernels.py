"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
import repro.kernels.ref as ref

RNG = np.random.default_rng(42)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,hd", [
        (1, 128, 1, 32), (2, 256, 2, 64), (1, 512, 4, 128), (1, 384, 2, 64),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, b, s, h, hd, causal):
        q, k, v = (_randn((b, s, h, hd)) for _ in range(3))
        got = ops.flash_attention(q, k, v, causal=causal,
                                  block_q=128, block_kv=128)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        q, k, v = (_randn((1, 256, 2, 64), jnp.bfloat16) for _ in range(3))
        got = ops.flash_attention(q, k, v, block_q=128, block_kv=128)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_block_size_invariance(self):
        q, k, v = (_randn((1, 512, 1, 64)) for _ in range(3))
        a = ops.flash_attention(q, k, v, block_q=128, block_kv=128)
        b = ops.flash_attention(q, k, v, block_q=256, block_kv=512)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestInt8Matmul:
    @pytest.mark.parametrize("m,k,n", [(64, 128, 64), (128, 256, 192), (256, 512, 128)])
    def test_exact_vs_ref(self, m, k, n):
        a = jnp.asarray(RNG.integers(-127, 128, (m, k)), jnp.int8)
        b = jnp.asarray(RNG.integers(-127, 128, (k, n)), jnp.int8)
        got = ops.int8_matmul(a, b, 0.02, 0.05, block_m=64, block_n=64, block_k=128)
        want = ref.int8_matmul_ref(a, b, 0.02, 0.05)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestSSDScan:
    @pytest.mark.parametrize("nc,b,h,p,n", [(4, 1, 2, 8, 4), (8, 2, 4, 16, 8),
                                            (16, 1, 8, 32, 16)])
    def test_matches_sequential_ref(self, nc, b, h, p, n):
        s_chunk = _randn((nc, b, h, p, n))
        decay = jnp.asarray(RNG.uniform(0.3, 1.0, (nc, b, h)), jnp.float32)
        hp, hf = ops.ssd_scan(s_chunk, decay, block_bh=min(4, b * h))
        hp_r, hf_r = ref.ssd_scan_ref(s_chunk, decay)
        np.testing.assert_allclose(np.asarray(hp), np.asarray(hp_r), atol=1e-5)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_r), atol=1e-5)


class TestMoeGMM:
    @pytest.mark.parametrize("e,c,d,f", [(2, 32, 64, 32), (4, 64, 128, 96),
                                         (8, 128, 64, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_einsum(self, e, c, d, f, dtype):
        x = _randn((e, c, d), dtype)
        w = _randn((e, d, f), dtype, 0.1)
        got = ops.moe_gmm(x, w, block_c=32, block_f=32, block_d=64)
        want = ref.moe_gmm_ref(x, w)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


class TestWinogradConv:
    @pytest.mark.parametrize("b,hw,c,k", [(1, 8, 16, 16), (2, 12, 64, 64),
                                          (1, 16, 32, 48), (1, 7, 16, 16)])
    def test_matches_direct_conv(self, b, hw, c, k):
        x = _randn((b, hw, hw, c))
        w = _randn((3, 3, c, k), scale=0.1)
        got = ops.winograd_conv2d(x, w, block_t=32, block_k=16)
        want = ref.winograd_conv_ref(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-3)

    def test_tile_roundtrip(self):
        x = _randn((2, 10, 10, 4))
        t = ref.extract_winograd_tiles(x)
        assert t.shape == (2 * 5 * 5, 4, 4, 4)


class TestTreeGatherPallas:
    """Pallas tree-gather (interpret on CPU) vs the numpy oracle.

    Shapes chosen to hit the padding paths: rows not a block multiple,
    trees far from the 128-lane pad, single-row / single-tree banks.
    """

    def _fit(self, n_trees, depth=3, n=120, d=5, seed=0):
        from repro.core.predictors import GBDTPredictor

        rng = np.random.default_rng(seed)
        x = np.abs(rng.standard_normal((n, d))) * np.linspace(1, 20, d)
        y = x @ rng.random(d) + 0.1
        m = GBDTPredictor(n_stages=n_trees, max_depth=depth).fit(x, y)
        return m, rng

    @pytest.mark.parametrize("rows,trees,depth", [
        (1, 1, 1), (7, 3, 2), (64, 10, 3), (257, 20, 4), (300, 130, 2),
    ])
    def test_matches_numpy_oracle(self, rows, trees, depth):
        from repro.kernels.tree_gather_pallas import predict_trees_pallas

        m, rng = self._fit(trees, depth=depth, seed=rows + trees)
        q = np.abs(rng.standard_normal((rows, 5))) * np.linspace(1, 20, 5)
        flat = m.flat()
        xs = m.scaler.transform(q)
        want = flat.predict_trees(xs, backend="numpy")
        got = predict_trees_pallas(flat, xs)
        assert got.shape == want.shape == (rows, trees)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)

    def test_matches_jax_backend_bitwise(self):
        # Both device tiers run float32 with the same `xv <= thr`
        # compare form, so they agree exactly, not just approximately.
        m, rng = self._fit(16, seed=7)
        q = np.abs(rng.standard_normal((200, 5))) * np.linspace(1, 20, 5)
        flat = m.flat()
        xs = m.scaler.transform(q)
        jx = flat.predict_trees(xs, backend="jax")
        pls = flat.predict_trees(xs, backend="pallas")
        assert np.array_equal(jx, pls)

    def test_block_size_invariance(self):
        from repro.kernels.tree_gather_pallas import predict_trees_pallas

        m, rng = self._fit(12, seed=3)
        q = np.abs(rng.standard_normal((513, 5))) * np.linspace(1, 20, 5)
        xs = m.scaler.transform(q)
        a = predict_trees_pallas(m.flat(), xs, block_rows=128)
        b = predict_trees_pallas(m.flat(), xs, block_rows=512)
        assert np.array_equal(a, b)

    def test_vmem_guard_raises_for_oversized_cell(self):
        from repro.kernels import tree_gather_pallas as tgp

        m, rng = self._fit(4, seed=5)
        xs = m.scaler.transform(
            np.abs(rng.standard_normal((16, 5))) * np.linspace(1, 20, 5))
        db = m.flat().device_bank()
        xd = db.stage_input(xs, sharded=False)
        real = tgp.VMEM_BUDGET_BYTES
        try:
            tgp.VMEM_BUDGET_BYTES = 1024
            with pytest.raises(ValueError, match="VMEM budget"):
                tgp.gather_leaves_pallas(db, xd)
        finally:
            tgp.VMEM_BUDGET_BYTES = real

    def test_reuses_resident_bank(self):
        from repro.kernels.tree_gather_pallas import predict_trees_pallas

        m, rng = self._fit(8, seed=11)
        q = np.abs(rng.standard_normal((32, 5))) * np.linspace(1, 20, 5)
        flat = m.flat()
        xs = m.scaler.transform(q)
        predict_trees_pallas(flat, xs)
        db = flat._device_bank
        assert db is not None and db.uploads == 1
        predict_trees_pallas(flat, xs)
        # Same bank object, still one upload: the padded pallas view is
        # derived on-device and cached, never re-transferred.
        assert flat._device_bank is db and db.uploads == 1

"""Thread-safety of the serving stack under concurrent load.

N threads hammer the `MicroBatcher` and `LatencyService` directly and
over the socket, asserting the exactly-once contract end to end: no
request is lost (every future settles), none is duplicated
(`PendingResult` raises on double-resolution), and none is cross-wired
(every report's fingerprint matches the graph that asked for it, and
its value is bit-identical to an isolated single-threaded prediction).
Cache and `stats()` counters must stay consistent under races —
hits + misses always equals the number of graph queries answered.

The quick variants run everywhere; the `slow`-marked stress loops run
`RPC_STRESS_ITERS` iterations (default 100 — full depth locally; CI
sets a reduced count, see .github/workflows/ci.yml).
"""
import os
import threading

import numpy as np
import pytest

from repro.core.dataset import synthetic_graphs
from repro.core.nas_space import NASSpaceConfig, sample_architecture
from repro.core.profiler import DeviceSetting
from repro.pipeline import LatencyService, PredictorHub, ProfileStore
from repro.rpc.batcher import (BatchPolicy, ManualClock, MicroBatcher,
                               MonotonicClock)
from repro.rpc.client import LatencyClient
from repro.rpc.server import LatencyRPCServer
from repro.transfer import CostModelProfileSession
from repro.utils.lru import LRUCache, SegmentedLRUCache

SOURCE = DeviceSetting("cpu_f32", "float32", "op_by_op")
SPACE = NASSpaceConfig(resolution=16)

# Full depth (the acceptance bar) locally; CI reduces via env.
STRESS_ITERS = int(os.environ.get("RPC_STRESS_ITERS", "100"))


@pytest.fixture(scope="module")
def served():
    store = ProfileStore()
    session = CostModelProfileSession(store=store, seed=3)
    for g in synthetic_graphs(8, resolution=16):
        session.profile_graph(g, SOURCE)
    hub = PredictorHub()
    hub.train(store, SOURCE, "gbdt", hparams={"n_stages": 20}, min_samples=3)
    svc = LatencyService(hub, default_setting=SOURCE, predictor="gbdt")
    return {"hub": hub, "service": svc}


@pytest.fixture(scope="module")
def pool(served):
    """48 distinct candidate graphs + single-threaded reference values
    (computed on a *separate* service over the same hub, so the hammered
    service's cache state never feeds the expectation)."""
    graphs = [sample_architecture(s, SPACE) for s in range(500, 548)]
    ref_svc = LatencyService(served["hub"], default_setting=SOURCE,
                             predictor="gbdt")
    ref = {g.fingerprint(): ref_svc.predict_e2e(g).e2e_s for g in graphs}
    return {"graphs": graphs, "ref": ref}


def hammer(n_threads, fn):
    """Run ``fn(thread_idx)`` on N threads; re-raise the first failure."""
    errors = []

    def wrap(i):
        try:
            fn(i)
        except BaseException as exc:   # noqa: BLE001 — surface everything
            errors.append(exc)

    threads = [threading.Thread(target=wrap, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "hammer thread hung"
    if errors:
        raise errors[0]


# ---------------------------------------------------------------------------
# Batcher under concurrent submits (live worker, real clock)
# ---------------------------------------------------------------------------

class TestBatcherConcurrency:
    N_THREADS = 8
    PER_THREAD = 12

    def run_round(self, service, pool, *, policy=None):
        service.clear_cache()
        batcher = MicroBatcher(
            service, policy or BatchPolicy(max_batch=16, max_wait_ticks=2,
                                           max_queue=4096),
            clock=MonotonicClock(tick_s=1e-3))
        graphs, ref = pool["graphs"], pool["ref"]
        results = [[] for _ in range(self.N_THREADS)]

        def worker(i):
            # Overlapping slices: every graph is requested by >1 thread,
            # so cross-wired responses cannot cancel out.
            mine = [graphs[(i * 7 + k) % len(graphs)]
                    for k in range(self.PER_THREAD)]
            futs = [(g, batcher.submit(g)) for g in mine]
            for g, f in futs:
                results[i].append((g, f.result(30)))

        hammer(self.N_THREADS, worker)
        batcher.close()
        return batcher, results

    def check_round(self, pool, batcher, results):
        total = self.N_THREADS * self.PER_THREAD
        st = batcher.stats()
        assert st["submitted"] == total
        assert st["answered"] == total          # nothing lost
        assert st["failed"] == st["rejected"] == 0
        assert st["queued"] == 0
        assert st["batched_requests"] + st["short_circuits"] == total
        for per_thread in results:
            for g, rep in per_thread:
                assert rep.fingerprint == g.fingerprint()   # not cross-wired
                assert rep.e2e_s == pool["ref"][g.fingerprint()]  # bit-equal

    def test_hammer_exactly_once(self, served, pool):
        batcher, results = self.run_round(served["service"], pool)
        self.check_round(pool, batcher, results)
        # Counter consistency on the service side: every answered
        # request was accounted exactly once as a hit or a miss.
        st = served["service"].stats()
        assert st["hits"] + st["misses"] >= self.N_THREADS * self.PER_THREAD

    def test_tiny_batches_and_queue_pressure(self, served, pool):
        """max_batch=1 (pure unbatched path) still exactly-once."""
        batcher, results = self.run_round(
            served["service"], pool,
            policy=BatchPolicy(max_batch=1, max_wait_ticks=0,
                               max_queue=4096))
        self.check_round(pool, batcher, results)

    @pytest.mark.slow
    def test_stress_iterations(self, served, pool):
        """The acceptance bar: STRESS_ITERS rounds, zero lost /
        duplicated / cross-wired responses."""
        for it in range(STRESS_ITERS):
            policy = BatchPolicy(max_batch=4 + (it % 13),
                                 max_wait_ticks=it % 3,
                                 max_queue=4096)
            batcher, results = self.run_round(served["service"], pool,
                                              policy=policy)
            self.check_round(pool, batcher, results)


# ---------------------------------------------------------------------------
# LatencyService hammered directly (no batcher)
# ---------------------------------------------------------------------------

class TestServiceConcurrency:
    def test_predict_e2e_and_batch_mixed(self, served, pool):
        svc = served["service"]
        svc.clear_cache()
        graphs, ref = pool["graphs"], pool["ref"]
        counted = [0] * 8

        def worker(i):
            if i % 2 == 0:
                for k in range(6):
                    g = graphs[(i * 11 + k) % len(graphs)]
                    rep = svc.predict_e2e(g)
                    assert rep.fingerprint == g.fingerprint()
                    assert rep.e2e_s == ref[g.fingerprint()]
                    counted[i] += 1
            else:
                batch = [graphs[(i * 5 + k) % len(graphs)] for k in range(9)]
                reps = svc.predict_batch(batch)
                assert [r.fingerprint for r in reps] == \
                    [g.fingerprint() for g in batch]
                assert [r.e2e_s for r in reps] == \
                    [ref[g.fingerprint()] for g in batch]
                counted[i] += len(batch)

        before = svc.stats()
        hammer(8, worker)
        after = svc.stats()
        queries = sum(counted)
        assert (after["hits"] - before["hits"]) + \
            (after["misses"] - before["misses"]) == queries
        assert after["size"] <= after["capacity"]

    def test_stats_snapshot_while_serving(self, served, pool):
        svc = served["service"]
        svc.clear_cache()
        stop = threading.Event()
        graphs = pool["graphs"]

        def reader(i):
            while not stop.is_set():
                st = svc.stats()
                assert st["hits"] >= 0 and st["misses"] >= 0
                assert st["size"] <= st["capacity"]
                info = svc.cache_info()
                assert info["size"] <= info["capacity"]

        def writer(i):
            try:
                for k in range(40):
                    svc.predict_e2e(graphs[(i + k) % len(graphs)])
            finally:
                stop.set()

        hammer(4, lambda i: writer(i) if i < 2 else reader(i))

    def test_cache_peek_semantics(self, served, pool):
        svc = served["service"]
        svc.clear_cache()
        g = pool["graphs"][0]
        before = svc.stats()
        assert svc.cache_peek(g) is None
        mid = svc.stats()
        assert (mid["hits"], mid["misses"]) == \
            (before["hits"], before["misses"])    # peek-miss counts nothing
        direct = svc.predict_e2e(g)
        hit = svc.cache_peek(g)
        assert hit is not None and hit.from_cache
        assert hit.e2e_s == direct.e2e_s
        assert svc.stats()["hits"] == mid["hits"] + 1

    @pytest.mark.slow
    def test_small_cache_eviction_races(self, served, pool):
        """A 8-entry cache under 8 threads: constant eviction pressure on
        `_insert` must never corrupt the LRU or produce wrong values."""
        svc = LatencyService(served["hub"], default_setting=SOURCE,
                             predictor="gbdt", cache_size=8)
        graphs, ref = pool["graphs"], pool["ref"]
        iters = max(STRESS_ITERS // 4, 5)

        def worker(i):
            for k in range(iters):
                g = graphs[(i * 13 + k) % len(graphs)]
                rep = svc.predict_e2e(g)
                assert rep.fingerprint == g.fingerprint()
                assert rep.e2e_s == ref[g.fingerprint()]

        hammer(8, worker)
        st = svc.stats()
        assert st["size"] <= 8
        assert st["hits"] + st["misses"] == 8 * iters


# ---------------------------------------------------------------------------
# The LRU primitives themselves (the bugs this suite exposed live here)
# ---------------------------------------------------------------------------

class TestLRUThreadSafety:
    KEYS = 128

    def test_lru_cache_hammer(self):
        cache = LRUCache(maxsize=32)

        def worker(i):
            for k in range(600):
                key = (i * 31 + k) % self.KEYS
                got = cache.get(key)
                if got is not None:
                    # A key must only ever map to its own value.
                    assert got == key * 3
                cache[key] = key * 3
                _ = key in cache
                if k % 64 == 0:
                    assert len(cache) <= 32

        hammer(8, worker)
        assert len(cache) <= 32
        for key in list(dict(cache)):
            assert cache[key] == key * 3

    def test_segmented_lru_hammer(self):
        cache = SegmentedLRUCache(probation=24, protected=16)

        def worker(i):
            for k in range(600):
                key = (i * 17 + k) % self.KEYS
                got = cache.get(key)
                if got is not None:
                    assert got == key * 7
                cache.put(key, key * 7, protect=(key % 5 == 0))
                if k % 64 == 0:
                    info = cache.info()
                    assert info["probation"] <= info["probation_capacity"]
                    assert info["protected"] <= info["protected_capacity"]

        hammer(8, worker)
        info = cache.info()
        assert info["size"] <= info["capacity"]

    def test_feature_cache_concurrent_featurization(self, pool):
        """The process-wide GraphFeatures cache (SegmentedLRUCache) under
        concurrent cached/pinned featurization of the same graphs."""
        from repro.core.features import graph_features

        graphs = pool["graphs"][:16]

        def worker(i):
            for k in range(40):
                g = graphs[(i + k) % len(graphs)]
                gf = graph_features(g, pin=(i % 2 == 0))
                assert gf.fingerprint == g.fingerprint()
                assert gf.num_nodes == len(g.nodes)

        hammer(8, worker)


# ---------------------------------------------------------------------------
# Socket path end to end under client-side thread pressure
# ---------------------------------------------------------------------------

class TestSocketConcurrency:
    def test_many_client_threads_one_connection(self, served, pool):
        server = LatencyRPCServer(
            served["service"],
            policy=BatchPolicy(max_batch=16, max_wait_ticks=2,
                               max_queue=4096))
        host, port = server.start()
        served["service"].clear_cache()
        graphs, ref = pool["graphs"], pool["ref"]
        try:
            with LatencyClient(host, port, timeout=60.0) as cli:
                def worker(i):
                    for k in range(8):
                        g = graphs[(i * 19 + k) % len(graphs)]
                        rep = cli.predict_e2e(g)
                        assert rep.fingerprint == g.fingerprint()
                        assert rep.e2e_s == ref[g.fingerprint()]

                hammer(8, worker)
                st = cli.stats()
                assert st["batcher"]["failed"] == 0
                assert st["batcher"]["rejected"] == 0
                assert st["server"]["errors"] == 0
        finally:
            server.stop()

    @pytest.mark.slow
    def test_socket_stress_rounds(self, served, pool):
        server = LatencyRPCServer(
            served["service"],
            policy=BatchPolicy(max_batch=8, max_wait_ticks=1,
                               max_queue=4096))
        host, port = server.start()
        graphs, ref = pool["graphs"], pool["ref"]
        rounds = max(STRESS_ITERS // 5, 4)
        try:
            with LatencyClient(host, port, timeout=60.0) as cli:
                for r in range(rounds):
                    served["service"].clear_cache()
                    reports = cli.predict_pipelined(
                        [graphs[(r + k) % len(graphs)] for k in range(24)])
                    for k, rep in enumerate(reports):
                        g = graphs[(r + k) % len(graphs)]
                        assert rep.fingerprint == g.fingerprint()
                        assert rep.e2e_s == ref[g.fingerprint()]
                st = cli.stats()
                assert st["batcher"]["failed"] == 0
        finally:
            server.stop()

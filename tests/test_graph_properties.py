"""Property-fuzz layer over the graph pipeline (hypothesis; skip-clean).

Seeded strategies draw (a) random-wired/elastic genotypes through the
real samplers and (b) wilder arbitrary-fanout DAGs than any generator
emits (duplicate operands, diamonds, multi-output heads), then assert
the invariants the rest of the stack leans on:

  * fusion conserves ops — every original op lands in exactly one
    group, one fused node per group, no dangling tensor references;
  * fusion introduces no cycles (`validate` re-checks topo order);
  * fused latency ≤ sum of parts under the roofline cost model
    (element-wise tails add no flops, merged bytes never exceed the
    parts, each merge saves one kernel launch);
  * `fuse_graph` is idempotent — re-fusing a fused graph is a no-op;
  * featurize→predict parity: "jax" and "pallas" service backends agree
    tightly, "numpy" (float64 trees) agrees within tolerance.

Profiles: "dev" (default, small; keeps tier-1 fast) and "ci"
(HYPOTHESIS_PROFILE=ci; ≥500 examples total, derandomized so a CI
failure reproduces locally with the same seed).
"""
import json
import os

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.cost_model import graph_cost
from repro.core.dataset import synthetic_graphs
from repro.core.fusion import fuse_graph
from repro.core.ir import OpGraph
from repro.core.nas_space import (NASSpaceConfig, RandomWiredConfig,
                                  RandomWiredGenotype, decode_genotype,
                                  sample_elastic_genotype, sample_random_wired)
from repro.core.profiler import DeviceSetting
from repro.pipeline import LatencyService, PredictorHub, ProfileStore
from repro.search import SupernetQuality, grow, mutate, repair, shrink
from repro.transfer import CostModelProfileSession

settings.register_profile(
    "dev", max_examples=10, derandomize=True, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])
settings.register_profile(
    "ci", max_examples=80, derandomize=True, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))

SOURCE = DeviceSetting("cpu_f32", "float32", "op_by_op")
SPACE = NASSpaceConfig(resolution=16)


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

@st.composite
def rw_genotypes(draw):
    """Random-wired genotypes through the real seeded samplers."""
    seed = draw(st.integers(0, 2**31 - 1))
    cfg = RandomWiredConfig(
        model=draw(st.sampled_from(("ws", "er", "ba"))),
        stages=draw(st.integers(1, 2)),
        nodes_per_stage=draw(st.integers(3, 7)),
        stem_c=8, channel_scale=0.25,
        encdec_prob=1.0 if draw(st.booleans()) else 0.0)
    return sample_random_wired(seed, cfg)


_EW_UNARY = ("sqrt", "abs", "square")
_EW_BINARY = ("add", "mul", "maximum")


@st.composite
def wild_graphs(draw):
    """Arbitrary-fanout DAGs, wilder than any generator: every op is
    shape-preserving so any tensor can feed any later op — including
    the same tensor twice into one binop (the diamond-collapse case)."""
    g = OpGraph("fuzz")
    c = draw(st.sampled_from((4, 8)))
    shape = (1, 8, 8, c)
    tensors = [g.add_input(shape)]
    n_ops = draw(st.integers(2, 10))
    for _ in range(n_ops):
        kind = draw(st.sampled_from(("conv", "dw", "unary", "binary", "act")))
        src = tensors[draw(st.integers(0, len(tensors) - 1))]
        if kind == "conv":
            (y,) = g.add_op("conv2d", [src], [shape],
                            {"kernel_h": 3, "kernel_w": 3, "stride": 1,
                             "groups": 1, "act": None, "padding": "SAME"})
        elif kind == "dw":
            (y,) = g.add_op("dwconv2d", [src], [shape],
                            {"kernel_h": 3, "kernel_w": 3, "stride": 1,
                             "act": None, "padding": "SAME"})
        elif kind == "unary":
            (y,) = g.add_op("elementwise", [src], [shape],
                            {"ew_kind": draw(st.sampled_from(_EW_UNARY))})
        elif kind == "binary":
            rhs = tensors[draw(st.integers(0, len(tensors) - 1))]
            (y,) = g.add_op("elementwise", [src, rhs], [shape],
                            {"ew_kind": draw(st.sampled_from(_EW_BINARY))})
        else:
            (y,) = g.add_op("activation", [src], [shape],
                            {"act": draw(st.sampled_from(("relu", "sigmoid")))})
        tensors.append(y)
    consumed = {t for n in g.nodes for t in n.inputs}
    for t in tensors[1:]:
        if t not in consumed:
            g.mark_output(t)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Fusion invariants
# ---------------------------------------------------------------------------

@given(g=wild_graphs())
def test_fusion_conserves_nodes_and_edges(g):
    groups, fused = fuse_graph(g)
    # Every original op in exactly one group; one fused node per group.
    member_ids = sorted(oid for gr in groups for oid in gr.op_ids)
    assert member_ids == sorted(n.op_id for n in g.nodes)
    assert len(member_ids) == len(set(member_ids))
    assert len(fused.nodes) == len(groups)
    # No dangling tensor references, and the graph interface survives.
    produced = set(fused.input_ids)
    for n in fused.nodes:
        produced.update(n.outputs)
    for n in fused.nodes:
        assert set(n.inputs) <= produced
    assert set(fused.output_ids) <= produced
    assert fused.output_ids == g.output_ids


@given(g=wild_graphs())
def test_fusion_introduces_no_cycles(g):
    _, fused = fuse_graph(g)
    fused.validate()   # re-checks topological order == acyclicity


@given(g=wild_graphs())
def test_fused_latency_at_most_sum_of_parts(g):
    before = graph_cost(g)
    _, fused = fuse_graph(g)
    after = graph_cost(fused)
    assert after["latency_s"] <= before["latency_s"] * (1 + 1e-12) + 1e-15


def _structure(g):
    """Name-free structural identity (fuse_graph re-suffixes the name)."""
    return ([(n.op_id, n.op_type, n.inputs, n.outputs, n.params, n.fused)
             for n in g.nodes], g.input_ids, g.output_ids)


@given(g=wild_graphs())
def test_fusion_is_idempotent(g):
    _, once = fuse_graph(g)
    _, twice = fuse_graph(once)
    assert _structure(twice) == _structure(once)


# ---------------------------------------------------------------------------
# Genotype properties
# ---------------------------------------------------------------------------

@given(gt=rw_genotypes())
def test_random_wired_decode_roundtrip_deterministic(gt):
    g1 = decode_genotype(gt, SPACE)           # validates internally
    clone = RandomWiredGenotype.from_json(json.loads(json.dumps(gt.to_json())))
    assert clone == gt and clone.digest() == gt.digest()
    assert decode_genotype(clone, SPACE).fingerprint() == g1.fingerprint()


@given(gt=rw_genotypes(), seed=st.integers(0, 2**31 - 1),
       n=st.integers(1, 6))
def test_random_wired_mutation_chain_stays_canonical(gt, seed, n):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        gt = mutate(gt, rng, SPACE)
    assert repair(gt, SPACE) == gt
    decode_genotype(gt, SPACE)   # still decodes + validates


@given(seed=st.integers(0, 2**31 - 1), step=st.integers(0, 2**31 - 1))
def test_supernet_quality_monotone_under_shrink_grow(seed, step):
    gt = sample_elastic_genotype(seed, SPACE)
    q = SupernetQuality(seed=1)
    base = q(gt)
    # Same rng seed → shrink/grow hit the same (block, knob) site.
    shrunk = shrink(gt, np.random.default_rng(step), SPACE)
    grown = grow(gt, np.random.default_rng(step), SPACE)
    assert q(shrunk) <= base + 1e-12
    assert q(grown) >= base - 1e-12


# ---------------------------------------------------------------------------
# Featurize → predict parity across service backends
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def backend_services():
    store = ProfileStore()
    session = CostModelProfileSession(store=store, seed=3)
    for g in synthetic_graphs(8, resolution=16):
        session.profile_graph(g, SOURCE)
    hub = PredictorHub()
    hub.train(store, SOURCE, "gbdt", hparams={"n_stages": 20}, min_samples=3)
    return {b: LatencyService(hub, default_setting=SOURCE, predictor="gbdt",
                              inference_backend=b)
            for b in ("numpy", "jax", "pallas")}


@given(gt=rw_genotypes())
@settings(suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.function_scoped_fixture])
def test_predict_parity_across_backends(backend_services, gt):
    g = decode_genotype(gt, SPACE)
    e2e = {b: svc.predict_batch([g])[0].e2e_s
           for b, svc in backend_services.items()}
    # jax and pallas(interpret) run the same f32 tree math; only the
    # accumulation order differs, so agreement is tight but not bitwise.
    assert e2e["pallas"] == pytest.approx(e2e["jax"], rel=1e-6, abs=1e-12)
    # numpy trees run in f64; near-tie splits may route differently, so
    # the end-to-end sums agree within tolerance rather than bitwise.
    assert e2e["numpy"] == pytest.approx(e2e["jax"], rel=0.02, abs=1e-6)

"""Multi-device distributed tests (subprocess: 8 host devices).

The main test process sees 1 device (XLA device count locks at first
jax import), so sharding/pjit/pipeline tests run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# The sharding/pipeline stack targets the jax>=0.6 mesh APIs
# (jax.set_mesh, jax.shard_map, AxisType); on older jax the subprocess
# tests cannot run — skip them rather than fail the tier-1 suite.
import jax  # noqa: E402

requires_new_sharding = pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax, "shard_map")),
    reason="needs jax>=0.6 sharding APIs (jax.set_mesh / jax.shard_map)")


def run_subprocess(body: str, timeout: int = 420) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


@requires_new_sharding
def test_sharded_train_step_matches_single_device():
    out = run_subprocess("""
        import dataclasses
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.distributed.sharding import shard_params, input_shardings
        from repro.distributed.trainstep import init_train_state, make_train_step
        from repro.launch.mesh import make_mesh

        cfg = get_arch("qwen2-72b").reduced()
        model = build_model(cfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                 "labels": jnp.ones((8, 32), jnp.int32)}
        step = make_train_step(model)
        # single device reference
        s1, m1 = jax.jit(step)(state, batch)
        # sharded on a (2, 4) mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        with jax.set_mesh(mesh):
            pshard = shard_params(jax.eval_shape(lambda: state.params), mesh)
            s2, m2 = jax.jit(step)(state, batch)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 5e-2, (float(m1["loss"]), float(m2["loss"]))
        print("LOSS_MATCH", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "LOSS_MATCH" in out


@requires_new_sharding
def test_fsdp_gather_numerics_match_tp():
    out = run_subprocess("""
        import dataclasses
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.launch.mesh import make_mesh

        base = get_arch("qwen2-72b").reduced()
        mesh = make_mesh((2, 4), ("data", "model"))
        batch = {"tokens": jnp.ones((4, 32), jnp.int32),
                 "labels": jnp.ones((4, 32), jnp.int32)}
        losses = {}
        for fsdp in (False, True):
            cfg = dataclasses.replace(base, fsdp_gather=fsdp, seq_shard=fsdp)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            with jax.set_mesh(mesh):
                loss, _ = jax.jit(model.loss)(params, batch)
            losses[fsdp] = float(loss)
        assert abs(losses[True] - losses[False]) < 5e-2, losses
        print("FSDP_MATCH", losses)
    """)
    assert "FSDP_MATCH" in out


@requires_new_sharding
def test_pipeline_parallel_matches_sequential():
    out = run_subprocess("""
        from functools import partial
        from repro.distributed.pipeline import (
            pipeline_forward, split_layers_to_stages, pipeline_bubble_fraction)
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(0)
        L, d = 8, 16
        ws = jnp.asarray(rng.standard_normal((L, d, d)) * 0.1, jnp.float32)

        def layer_fn(w, x):
            return jnp.tanh(x @ w)

        M, mb, s = 4, 2, 4
        x = jnp.asarray(rng.standard_normal((M, mb, s, d)), jnp.float32)

        # sequential reference
        ref = x
        for i in range(L):
            ref = layer_fn(ws[i], ref)

        mesh = make_mesh((4,), ("pipe",))
        stages = split_layers_to_stages(ws, 4)
        out = pipeline_forward(layer_fn, stages, x, mesh=mesh, axis="pipe")
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        assert 0 < pipeline_bubble_fraction(4, 4) < 1
        print("PIPELINE_MATCH", err)
    """)
    assert "PIPELINE_MATCH" in out


@requires_new_sharding
def test_compressed_psum_under_shard_map():
    out = run_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)),
                        jnp.float32)

        def body(xl):
            return compressed_psum(xl[0], "data")

        f = jax.shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P())
        got = f(x)
        want = x.sum(0)
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel < 0.02, rel
        print("PSUM_OK", rel)
    """)
    assert "PSUM_OK" in out


def test_elastic_mesh_shapes():
    import numpy as np
    from repro.launch.mesh import elastic_mesh_shape
    assert elastic_mesh_shape(256, model_parallel=16) == ((16, 16), ("data", "model"))
    assert elastic_mesh_shape(192, model_parallel=16) == ((12, 16), ("data", "model"))
    # degraded pod: model axis shrinks to fit
    shape, axes = elastic_mesh_shape(24, model_parallel=16)
    assert int(np.prod(shape)) == 24


@requires_new_sharding
def test_elastic_recovery_roundtrip(tmp_path):
    out = run_subprocess(f"""
        from repro.checkpoint import CheckpointManager
        from repro.distributed.elastic import recover
        from repro.distributed.trainstep import init_train_state
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.launch.mesh import make_mesh

        cfg = get_arch("granite-moe-1b-a400m").reduced()
        model = build_model(cfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        ckpt = CheckpointManager(r"{tmp_path}", async_save=False)
        ckpt.save(42, state, {{"mesh_shape": [8]}})
        # recover onto a DIFFERENT mesh (2x4) — elastic reshard
        mesh = make_mesh((2, 4), ("data", "model"))
        restored, plan = recover(ckpt, state, mesh=mesh)
        assert plan.resumed and plan.step == 42
        a = np.asarray(jax.tree_util.tree_leaves(state.params)[0])
        b = np.asarray(jax.tree_util.tree_leaves(restored.params)[0])
        np.testing.assert_array_equal(a, b)
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


@requires_new_sharding
def test_dryrun_reduced_cell_on_8_devices():
    """End-to-end mini dry-run: reduced arch on a small mesh, full record."""
    out = run_subprocess("""
        from repro.launch.dryrun import run_cell
        from repro.launch.mesh import make_mesh
        from repro.configs import get_arch

        mesh = make_mesh((2, 4), ("data", "model"))
        rec = run_cell("granite-moe-1b-a400m", "train_4k", mesh,
                       cfg_override=get_arch("granite-moe-1b-a400m").reduced())
        assert rec["ok"], rec.get("error")
        assert rec["cost"]["flops_per_device"] > 0
        assert rec["memory"]["temp_bytes"] > 0
        print("DRYRUN_OK", rec["collective_bytes"])
    """)
    assert "DRYRUN_OK" in out


# Flush sharding only needs the stable jax.experimental.shard_map (old
# API), so unlike the mesh-API tests above it runs on this container.
try:
    from jax.experimental.shard_map import shard_map as _sm  # noqa: F401
    _HAS_SHARD_MAP = True
except Exception:                                 # pragma: no cover
    _HAS_SHARD_MAP = False

requires_shard_map = pytest.mark.skipif(
    not _HAS_SHARD_MAP, reason="needs jax.experimental.shard_map")


@requires_shard_map
def test_sharded_tree_flush_matches_numpy_and_is_deterministic():
    """Giant flushes shard rows across the device mesh; reassembly is
    row-order deterministic and bank upload stays at one."""
    out = run_subprocess("""
        from repro.core.predictors import GBDTPredictor

        rng = np.random.default_rng(0)
        x = np.abs(rng.standard_normal((200, 8))) * np.linspace(1, 30, 8)
        y = x @ rng.random(8) + 0.1
        m = GBDTPredictor(n_stages=20).fit(x, y)
        # 2050 rows: above SHARD_MIN_ROWS and not a multiple of the 8
        # forced host devices, so the pad-and-slice path is exercised.
        q = np.abs(rng.standard_normal((2050, 8))) * np.linspace(1, 30, 8)
        flat = m.flat()
        xs = m.scaler.transform(q)
        ref = flat.predict_trees(xs, backend="numpy")
        got = flat.predict_trees(xs, backend="jax")
        db = flat.device_bank()
        assert db.mesh is not None and db.stats()["sharded"]
        assert got.shape == ref.shape
        assert np.allclose(got, ref, rtol=1e-4, atol=1e-7)
        again = flat.predict_trees(xs, backend="jax")
        assert np.array_equal(got, again)          # deterministic reassembly
        assert db.uploads == 1                     # bank uploaded once
        # Fused device scoring rides the same sharded staging.
        host = m.predict(q)
        dev = m.predict_on_device(np.asarray(q, np.float32))
        assert np.allclose(dev, host, rtol=1e-3, atol=1e-5)
        # Small flushes stay unsharded (below SHARD_MIN_ROWS).
        small = flat.predict_trees(xs[:64], backend="jax")
        assert np.allclose(small, ref[:64], rtol=1e-4, atol=1e-7)
        print("SHARDED_FLUSH_OK")
    """)
    assert "SHARDED_FLUSH_OK" in out

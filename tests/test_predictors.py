"""Predictor family tests + hypothesis property tests on invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.predictors import (
    GBDTPredictor, LassoPredictor, MLPPredictor, RandomForestPredictor,
    Standardizer, make_predictor,
)
from repro.core.predictors.trees import RegressionTree


def _linear_data(n=300, d=6, seed=0):
    rng = np.random.default_rng(seed)
    x = np.abs(rng.standard_normal((n, d))) * np.linspace(1, 50, d)
    w = np.array([2.0, 0, 0.5, 0, 0, 1.0])
    y = x @ w + 0.3
    return x, y


@pytest.mark.parametrize("name,tol", [
    ("lasso", 0.05), ("rf", 0.25), ("gbdt", 0.10), ("mlp", 0.30)])
def test_predictor_fits_linear_relation(name, tol):
    x, y = _linear_data()
    m = make_predictor(name, **({"max_epochs": 1200} if name == "mlp" else {}))
    m.fit(x[:250], y[:250])
    assert m.mape(x[250:], y[250:]) < tol


def test_lasso_nonneg_weights():
    x, y = _linear_data()
    m = LassoPredictor(alpha=1e-3).fit(x, y)
    assert (m.feature_weights >= 0).all()


def test_lasso_sparsity_increases_with_alpha():
    x, y = _linear_data()
    w_small = LassoPredictor(alpha=1e-4).fit(x, y).feature_weights
    w_big = LassoPredictor(alpha=10.0).fit(x, y).feature_weights
    assert (w_big > 1e-8).sum() <= (w_small > 1e-8).sum()


def test_predictions_nonnegative():
    x, y = _linear_data()
    for name in ("lasso", "rf", "gbdt"):
        m = make_predictor(name).fit(x, y)
        assert (m.predict(-np.abs(x)) >= 0).all()


class TestStandardizer:
    @given(st.integers(2, 40), st.integers(1, 6))
    @settings(max_examples=20, deadline=None)
    def test_transform_zero_mean_unit_std(self, n, d):
        rng = np.random.default_rng(n * 7 + d)
        x = rng.standard_normal((n, d)) * 10 + 5
        s = Standardizer().fit(x)
        z = s.transform(x)
        np.testing.assert_allclose(z.mean(axis=0), 0, atol=1e-9)
        keep = x.std(axis=0) > 1e-12
        np.testing.assert_allclose(z.std(axis=0)[keep], 1, atol=1e-9)

    def test_constant_feature_no_nan(self):
        x = np.ones((10, 3))
        z = Standardizer().fit(x).transform(x)
        assert np.isfinite(z).all()


class TestRegressionTree:
    @given(st.integers(5, 60))
    @settings(max_examples=15, deadline=None)
    def test_perfect_split_recovery(self, n):
        rng = np.random.default_rng(n)
        x = rng.standard_normal((n, 2))
        y = np.where(x[:, 0] > 0, 5.0, 1.0)
        t = RegressionTree(max_depth=3).fit(x, y)
        pred = t.predict(x)
        assert np.abs(pred - y).max() < 1e-9

    def test_weighted_fit_prefers_heavy_samples(self):
        x = np.array([[0.0], [1.0]] * 20)
        y = np.array([1.0, 10.0] * 20)
        w = np.array([100.0, 1e-6] * 20)
        t = RegressionTree(max_depth=1, min_samples_split=2).fit(x, y, sample_weight=w)
        # With all weight on y=1 samples, a depth-0-equivalent leaf ≈ 1.
        assert abs(t.predict(np.array([[0.0]]))[0] - 1.0) < 1e-3

    def test_monotone_feature_scaling_invariance(self):
        x, y = _linear_data(100)
        t1 = RegressionTree(max_depth=4, seed=1).fit(x, y)
        t2 = RegressionTree(max_depth=4, seed=1).fit(x * 100.0, y)
        np.testing.assert_allclose(t1.predict(x), t2.predict(x * 100.0), rtol=1e-9)


def test_gbdt_improves_with_stages():
    x, y = _linear_data(400, seed=3)
    y = y + 0.1 * x[:, 0] ** 2
    few = GBDTPredictor(n_stages=5).fit(x[:300], y[:300]).mape(x[300:], y[300:])
    many = GBDTPredictor(n_stages=150).fit(x[:300], y[:300]).mape(x[300:], y[300:])
    assert many < few


# ---------------------------------------------------------------------------
# Flattened fast path ≡ node-walk oracle (property tests)
# ---------------------------------------------------------------------------

def _random_regression(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)) * np.linspace(1, 20, d)
    y = x @ rng.random(d) + rng.standard_normal(n)
    return x, y


class TestFlattenedParity:
    """Batched struct-of-arrays traversal must be bit-identical to the
    per-row node walk — including on training rows, which can sit
    exactly on split thresholds."""

    @given(st.integers(10, 120), st.integers(1, 6), st.integers(1, 10),
           st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_tree_parity(self, n, d, depth, seed):
        x, y = _random_regression(n, d, seed)
        t = RegressionTree(max_depth=depth, seed=seed).fit(x, y)
        q = np.vstack([x, _random_regression(64, d, seed + 1)[0]])
        assert np.array_equal(t.predict(q), t.predict_oracle(q))

    @given(st.integers(30, 100), st.integers(2, 5), st.integers(1, 8),
           st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_rf_parity(self, n, d, n_trees, seed):
        x, y = _random_regression(n, d, seed)
        m = RandomForestPredictor(n_trees=n_trees, max_depth=6, seed=seed).fit(x, y)
        q = np.vstack([x, _random_regression(32, d, seed + 1)[0]])
        assert np.array_equal(m.predict(q), m.predict_oracle(q))

    @given(st.integers(30, 100), st.integers(2, 5), st.integers(1, 40),
           st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_gbdt_parity(self, n, d, n_stages, seed):
        x, y = _random_regression(n, d, seed)
        m = GBDTPredictor(n_stages=n_stages, seed=seed).fit(x, y)
        q = np.vstack([x, _random_regression(32, d, seed + 1)[0]])
        assert np.array_equal(m.predict(q), m.predict_oracle(q))

    def test_single_leaf_tree(self):
        # Constant labels → depth-0 tree (root is the only node).
        x = np.ones((10, 3))
        y = np.full(10, 7.0)
        t = RegressionTree().fit(x, y)
        assert t.flat().max_depth == 0
        assert np.array_equal(t.predict(x), np.full(10, 7.0))


class TestBackendParity:
    """numpy / jax / pallas(interpret) agreement on random banks.

    The device tiers run float32 while numpy runs float64, so two error
    sources exist: leaf-value rounding (bounded by the documented
    rtol=1e-4 tolerance) and ROUTING divergence when a query lands
    within float32 epsilon of a split threshold (a near-tie).  The
    property: every row either agrees within tolerance, or the bank
    provably contains a near-tie for that row — float32 disagreement is
    only ever tie-breaking, never wrong traversal.
    """

    # Fixed shapes keep jit/interpret caches warm across examples.
    N_ROWS, N_DIMS, N_STAGES = 24, 4, 5

    def _bank(self, seed):
        pytest.importorskip("jax")
        rng = np.random.default_rng(seed)
        x = np.abs(rng.standard_normal((80, self.N_DIMS))) * 10
        y = x @ rng.random(self.N_DIMS) + 0.1
        m = GBDTPredictor(n_stages=self.N_STAGES, max_depth=3).fit(x, y)
        return m, rng

    @staticmethod
    def _near_tie(flat, xs, row, rel=8 * np.finfo(np.float32).eps):
        internal = flat.feature >= 0
        f = flat.feature[internal]
        thr = flat.threshold[internal]
        gap = np.abs(xs[row, f] - thr)
        return bool((gap <= rel * np.maximum(1.0, np.abs(thr))).any())

    @given(st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_backends_agree_or_near_tie(self, seed):
        m, rng = self._bank(seed)
        flat = m.flat()
        xs = m.scaler.transform(
            np.abs(rng.standard_normal((self.N_ROWS, self.N_DIMS))) * 10)
        # Plant near-ties: aim half the rows directly at split
        # thresholds so tie-handling is exercised, not just sampled.
        internal = flat.feature >= 0
        if internal.any():
            nodes = np.flatnonzero(internal)
            for row in range(0, self.N_ROWS, 2):
                j = nodes[rng.integers(len(nodes))]
                xs[row, flat.feature[j]] = flat.threshold[j]
        ref = flat.predict_trees(xs, backend="numpy")
        jx = flat.predict_trees(xs, backend="jax")
        pls = flat.predict_trees(xs, backend="pallas")
        # The two float32 tiers share math and compare form: identical.
        assert np.array_equal(jx, pls)
        ok = np.isclose(jx, ref, rtol=2e-4, atol=1e-6).all(axis=1)
        for row in np.flatnonzero(~ok):
            assert self._near_tie(flat, xs, row), (
                f"row {row} disagrees with no near-tie threshold")

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_f32_representable_inputs_route_identically(self, seed):
        # When inputs and thresholds are exactly float32-representable,
        # routing cannot diverge: parity becomes (near-)exact equality
        # of the selected leaf values after one f64→f32 rounding.
        m, rng = self._bank(seed + 31337)
        flat = m.flat()
        flat.threshold = flat.threshold.astype(np.float32).astype(np.float64)
        xs = m.scaler.transform(
            np.abs(rng.standard_normal((self.N_ROWS, self.N_DIMS))) * 10)
        xs = xs.astype(np.float32).astype(np.float64)
        ref = flat.predict_trees(xs, backend="numpy")
        jx = flat.predict_trees(xs, backend="jax")
        np.testing.assert_allclose(jx, ref, rtol=1e-6, atol=1e-7)

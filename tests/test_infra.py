"""Infrastructure tests: checkpoint manager, data pipeline, straggler
planner, compression, schedules, HLO analysis."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.core.distributed_model import (
    Worker, WeightedSplitPlanner, equal_split_latency, graph_latency_multiworker,
    speedup_curve, weighted_split_latency,
)
from repro.data.pipeline import SyntheticLMData
from repro.distributed.compression import (
    compress_grads, compression_error, compression_init,
)
from repro.distributed.straggler import StragglerMonitor
from repro.optim.schedules import linear_warmup_cosine
from repro.utils.hlo_analysis import _shape_bytes, collect_collective_stats


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=False)
        tree = {"a": np.arange(10, dtype=np.float32),
                "b": {"c": np.ones((3, 4), np.float32)}}
        ckpt.save(5, tree, {"note": "x"})
        restored, meta = ckpt.restore(target=tree)
        np.testing.assert_array_equal(restored["a"], tree["a"])
        np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])
        assert meta["step"] == 5

    def test_latest_and_gc(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        tree = {"a": np.zeros(3, np.float32)}
        for s in (1, 2, 3, 4):
            ckpt.save(s, tree)
        assert ckpt.latest_step() == 4
        assert ckpt.all_steps() == [3, 4]  # gc keeps 2

    def test_tmp_dirs_ignored(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=False)
        tree = {"a": np.zeros(3, np.float32)}
        ckpt.save(1, tree)
        # simulate a crashed write
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert ckpt.latest_step() == 1

    def test_async_save(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), async_save=True)
        tree = {"a": np.arange(5, dtype=np.float32)}
        ckpt.save(7, tree)
        ckpt.wait()
        assert ckpt.latest_step() == 7
        ckpt.close()

    def test_restore_with_namedtuple_state(self, tmp_path):
        from repro.optim.adamw import adamw_init
        params = {"w": np.ones((4, 4), np.float32)}
        opt = adamw_init(params)
        ckpt = CheckpointManager(str(tmp_path), async_save=False)
        ckpt.save(1, {"params": params, "opt": opt})
        restored, _ = ckpt.restore(target={"params": params, "opt": opt})
        np.testing.assert_array_equal(np.asarray(restored["opt"].mu["w"]),
                                      np.asarray(opt.mu["w"]))


class TestDataPipeline:
    def test_deterministic_in_step(self):
        d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=4, seed=1)
        b1, b2 = d.batch_at(3), d.batch_at(3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_different_steps_differ(self):
        d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=4)
        assert not np.array_equal(d.batch_at(0)["tokens"], d.batch_at(1)["tokens"])

    def test_host_sharding_disjoint(self):
        kw = dict(vocab_size=100, seq_len=16, global_batch=8, host_count=2)
        d0 = SyntheticLMData(host_index=0, **kw)
        d1 = SyntheticLMData(host_index=1, **kw)
        assert d0.local_batch == 4
        assert not np.array_equal(d0.batch_at(0)["tokens"], d1.batch_at(0)["tokens"])

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=2)
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])

    def test_prefetch_iterator(self):
        d = SyntheticLMData(vocab_size=50, seq_len=8, global_batch=2)
        it = d.iterate(start_step=5)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], d.batch_at(5)["tokens"])


class TestStragglerModel:
    def test_equal_split_degrades_with_slow_worker(self):
        """Paper Fig. 2: medium+small slower than medium alone."""
        fast = [Worker("m", 1.0)]
        mixed = [Worker("m", 1.0), Worker("s", 0.4)]
        lat_fast = equal_split_latency(1.0, fast)
        lat_mixed = equal_split_latency(1.0, mixed)
        assert lat_mixed > lat_fast  # 0.5/0.4 = 1.25 > 1.0

    def test_weighted_split_never_worse_than_equal(self):
        for speeds in ([1.0, 0.3], [1.0, 1.0, 0.1], [0.5, 0.7, 0.9]):
            ws = [Worker(f"w{i}", s) for i, s in enumerate(speeds)]
            eq = equal_split_latency(1.0, ws)
            wt, shares = weighted_split_latency(1.0, ws)
            assert wt <= eq + 1e-12
            assert abs(sum(shares) - 1) < 1e-9

    def test_sublinear_speedup_curve(self):
        ops = [("conv2d", 1.0), ("elementwise", 0.2)]  # ew not parallelizable
        curve = speedup_curve(ops, [1, 2, 4], sync_overhead=0.01)
        assert curve[1] == pytest.approx(1.0)
        assert 1.0 < curve[2] < 2.0      # sublinear (Amdahl + sync)
        assert curve[2] < curve[4] < 4.0

    @given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=6))
    @settings(max_examples=30, deadline=None)
    def test_planner_shares_proportional_to_speed(self, times):
        planner = WeightedSplitPlanner()
        shares = planner.plan(times)
        assert abs(sum(shares) - 1) < 1e-9
        # faster (smaller time) → share at least as large (ties allowed)
        for i in range(len(times)):
            for j in range(len(times)):
                if times[i] < times[j]:
                    assert shares[i] >= shares[j] - 1e-12

    def test_monitor_detects_straggler_and_plans(self):
        m = StragglerMonitor(n_groups=4)
        m.update([1.0, 1.0, 1.0, 2.0])
        assert m.degraded_groups() == [3]
        plan = m.microbatch_plan(16)
        assert sum(plan) == 16
        assert plan[3] < plan[0]
        assert m.predicted_speedup(16) > 1.0


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        grads = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(512),
                                  jnp.float32)}
        state = compression_init(grads)
        total = jnp.zeros(512)
        exact = jnp.zeros(512)
        for _ in range(20):
            deq, state = compress_grads(grads, state)
            total = total + deq["w"]
            exact = exact + grads["w"].astype(jnp.float32)
        # accumulated compressed sum tracks the exact sum (error feedback)
        rel = float(jnp.linalg.norm(total - exact) / jnp.linalg.norm(exact))
        assert rel < 0.01

    def test_single_round_error_bounded(self):
        grads = {"w": jnp.asarray(np.random.default_rng(1).standard_normal(1024),
                                  jnp.float32)}
        err = float(compression_error(grads, compression_init(grads)))
        assert 0 < err < 0.05  # int8 quantization noise


class TestSchedules:
    def test_warmup_then_decay(self):
        lr0 = float(linear_warmup_cosine(0, base_lr=1.0, warmup_steps=10, total_steps=100))
        lr10 = float(linear_warmup_cosine(10, base_lr=1.0, warmup_steps=10, total_steps=100))
        lr99 = float(linear_warmup_cosine(99, base_lr=1.0, warmup_steps=10, total_steps=100))
        assert lr0 < lr10
        assert lr10 == pytest.approx(1.0, abs=0.01)
        assert lr99 < 0.2


class TestHLOAnalysis:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
        assert _shape_bytes("f32[]") == 4  # scalar: one element
        assert _shape_bytes("(bf16[4,4]{1,0}, s32[2]{0})") == 32 + 8

    def test_collects_from_real_hlo(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if jax.device_count() < 1:
            pytest.skip("no devices")
        # single-device program has no collectives
        f = jax.jit(lambda x: x @ x.T)
        txt = f.lower(jnp.ones((8, 8))).compile().as_text()
        stats = collect_collective_stats(txt)
        assert stats.total_count == 0

"""`repro.search` — predictor-in-the-loop NAS engine.

Covers: genotype sampling/decoding equivalence with the legacy
sample-only path, seeded determinism of mutate/crossover and whole
search runs, Pareto-front invariants (non-domination, bounded pruning,
crowding tie-breaks), checkpoint/resume bit-equivalence, the
one-predict_batch-per-device-per-generation contract, and multi-device
constraint filtering against a transfer-calibrated synthetic second
device.  Everything runs on the deterministic cost-model session — no
wall-clock measurement anywhere.
"""
import json
import os

import numpy as np
import pytest

from repro.core.dataset import synthetic_graphs
from repro.core.nas_space import (Genotype, NASSpaceConfig,
                                  RandomWiredConfig, RandomWiredGenotype,
                                  decode_genotype, genotype_from_json,
                                  genotype_from_rng, sample_architecture,
                                  sample_elastic_genotype, sample_genotype,
                                  sample_random_wired)
from repro.core.profiler import DeviceSetting
from repro.pipeline import LatencyService, PredictorHub, ProfileStore
from repro.search import (DeviceBudget, LatencyScorer, ParetoFront,
                          SearchConfig, SearchEngine, crossover,
                          crowding_distance, dominates, graph_flops,
                          graph_params, grow, make_quality, mutate,
                          mutate_elastic, nondominated_rank, random_genotype,
                          repair, shrink)
from repro.search.encoding import decode
from repro.transfer import (CostModelProfileSession, ReplayProfileSession,
                            SyntheticDevice, TransferEngine)

SOURCE = DeviceSetting("cpu_f32", "float32", "op_by_op")
TARGET = DeviceSetting("sim", "float32", "op_by_op", device="sim")
SPACE = NASSpaceConfig(resolution=16)


@pytest.fixture(scope="module")
def served():
    """Cost-model-profiled store + trained hub + service (+ budgets)."""
    store = ProfileStore()
    session = CostModelProfileSession(store=store, seed=3)
    graphs = synthetic_graphs(8, resolution=16)
    for g in graphs:
        session.profile_graph(g, SOURCE)
    hub = PredictorHub()
    hub.train(store, SOURCE, "gbdt", hparams={"n_stages": 20}, min_samples=3)
    svc = LatencyService(hub, default_setting=SOURCE, predictor="gbdt")
    e2e = [store.get_arch(SOURCE, g.fingerprint()).e2e_s for g in graphs]
    return {"store": store, "hub": hub, "service": svc,
            "budget_s": float(np.median(e2e))}


def small_config(**kw) -> SearchConfig:
    base = dict(population_size=12, generations=4, children_per_gen=10,
                tournament_size=4, seed=11, resolution=16, front_capacity=8)
    base.update(kw)
    return SearchConfig(**base)


# ---------------------------------------------------------------------------
# Genotype encoding: sampling parity, operators, repair
# ---------------------------------------------------------------------------

class TestEncoding:
    def test_sample_decode_matches_sample_architecture(self):
        for seed in (0, 7, 42):
            legacy = sample_architecture(seed, SPACE)
            gt = sample_genotype(seed, SPACE)
            again = decode_genotype(gt, SPACE, name=f"nas_{seed}")
            assert legacy.fingerprint() == again.fingerprint()

    def test_sampled_genotypes_are_canonical(self):
        for seed in range(20):
            gt = sample_genotype(seed, SPACE)
            assert repair(gt, SPACE) == gt

    def test_genotype_json_roundtrip_and_digest(self):
        gt = sample_genotype(3, SPACE)
        clone = Genotype.from_json(json.loads(json.dumps(gt.to_json())))
        assert clone == gt
        assert clone.digest() == gt.digest()

    def test_mutate_deterministic_per_seed(self):
        gt = sample_genotype(1, SPACE)
        a = mutate(gt, np.random.default_rng(5), SPACE)
        b = mutate(gt, np.random.default_rng(5), SPACE)
        assert a == b
        # A stream of mutations from one generator explores (digests vary).
        rng = np.random.default_rng(5)
        digests = {mutate(gt, rng, SPACE).digest() for _ in range(10)}
        assert len(digests) > 3

    def test_crossover_deterministic_and_blockwise(self):
        a, b = sample_genotype(1, SPACE), sample_genotype(2, SPACE)
        c1 = crossover(a, b, np.random.default_rng(9), SPACE)
        c2 = crossover(a, b, np.random.default_rng(9), SPACE)
        assert c1 == c2
        # Each block comes from one of the parents (up to context repair,
        # which may reset a group count the child's channels invalidate).
        for i, gene in enumerate(c1.blocks):
            assert (gene.kind, gene.out_c) in {
                (a.blocks[i].kind, a.blocks[i].out_c),
                (b.blocks[i].kind, b.blocks[i].out_c)}
        assert c1.head_c in (a.head_c, b.head_c)

    def test_mutation_chain_decodes_valid(self):
        # Long chains of edits (incl. kind flips + channel changes that
        # invalidate downstream groups/splits) must always decode.
        rng = np.random.default_rng(0)
        gt = random_genotype(rng, SPACE)
        for _ in range(60):
            gt = mutate(gt, rng, SPACE)
            g = decode(gt, SPACE)          # validate() runs inside
            assert g.num_ops() > 10

    def test_repair_fixes_stale_groups(self):
        gt = sample_genotype(4, SPACE)
        # Force an invalid group count onto the second block.
        from dataclasses import replace
        bad = gt.replace_block(1, replace(gt.blocks[1], kind="conv", groups=7))
        fixed = repair(bad, SPACE)
        in_c = fixed.blocks[0].out_c
        g = fixed.blocks[1].groups
        assert g == 1 or (in_c % g == 0 and fixed.blocks[1].out_c % g == 0)
        decode(fixed, SPACE)

    def test_quality_proxies(self):
        g = sample_architecture(0, SPACE)
        assert graph_flops(g) > 0
        assert graph_params(g) > 0
        assert make_quality("flops")(g) == pytest.approx(
            np.log(graph_flops(g)))
        assert make_quality("balanced")(g) < make_quality("flops")(g)
        with pytest.raises(ValueError):
            make_quality("nope")


# ---------------------------------------------------------------------------
# Pareto front invariants
# ---------------------------------------------------------------------------

class TestPareto:
    def test_no_dominated_member(self):
        rng = np.random.default_rng(0)
        front = ParetoFront()
        for i, p in enumerate(rng.random((200, 3))):
            front.add(f"k{i}", p)
        pts = front.objectives()
        for i in range(len(pts)):
            for j in range(len(pts)):
                if i != j:
                    assert not dominates(pts[i], pts[j])

    def test_add_semantics(self):
        front = ParetoFront()
        assert front.add("a", [1.0, 1.0])
        assert not front.add("b", [2.0, 2.0])       # dominated → rejected
        assert not front.add("dup", [1.0, 1.0])     # duplicate point
        assert front.add("c", [0.5, 2.0])           # trade-off admitted
        assert front.add("d", [0.5, 0.5])           # dominates a and c
        assert [k for k, _, _ in front.members()] == ["d"]

    def test_readd_with_changed_objectives_keeps_invariant(self):
        front = ParetoFront()
        assert front.add("d1", [1.0, 1.0])
        assert front.add("d3", [0.5, 2.0])          # trade-off member
        # Re-scoring d1 to a dominated point must re-run admission, not
        # silently overwrite — else the front holds a dominated member.
        assert not front.add("d1", [3.0, 3.0])
        assert [k for k, _, _ in front.members()] == ["d3"]
        # Re-scoring to a dominating point evicts the rest.
        assert front.add("d1", [0.1, 0.1])
        assert [k for k, _, _ in front.members()] == ["d1"]

    def test_capacity_prunes_least_crowded_keeps_extremes(self):
        front = ParetoFront(capacity=4)
        # A clean 1D trade-off line; "c2" sits in the densest region.
        pts = {"a": (0.0, 1.0), "b": (1.0, 0.0), "c1": (0.45, 0.55),
               "c2": (0.5, 0.5), "c3": (0.55, 0.45)}
        for k, p in pts.items():
            front.add(k, p)
        keys = {k for k, _, _ in front.members()}
        assert len(keys) == 4
        assert {"a", "b"} <= keys                   # extremes survive

    def test_crowding_tie_break_stable(self):
        # Equal-crowding interior points: pruning must pick the same
        # victim regardless of insertion order (digest tie-break).
        pts = {"a": (0.0, 1.0), "b": (1.0, 0.0),
               "m1": (0.25, 0.75), "m2": (0.5, 0.5), "m3": (0.75, 0.25)}
        orders = [list(pts), list(reversed(list(pts)))]
        survivors = []
        for order in orders:
            front = ParetoFront(capacity=4)
            for k in order:
                front.add(k, pts[k])
            survivors.append(sorted(k for k, _, _ in front.members()))
        assert survivors[0] == survivors[1]

    def test_front_json_roundtrip(self):
        front = ParetoFront(capacity=8)
        rng = np.random.default_rng(1)
        for i, p in enumerate(rng.random((50, 2))):
            front.add(f"k{i}", p)
        clone = ParetoFront.from_json(json.loads(json.dumps(front.to_json())))
        assert front.digest_equal(clone)

    def test_rank_and_crowding_shapes(self):
        pts = np.array([[0.0, 1.0], [1.0, 0.0], [0.5, 0.5], [1.0, 1.0]])
        ranks = nondominated_rank(pts)
        assert list(ranks[:3]) == [0, 0, 0] and ranks[3] == 1
        crowd = crowding_distance(pts[:3])
        assert np.isinf(crowd[0]) and np.isinf(crowd[1])


# ---------------------------------------------------------------------------
# Search runs: determinism, contract, resume, constraints
# ---------------------------------------------------------------------------

class TestSearchEngine:
    def test_run_deterministic_across_invocations(self, served):
        budgets = [DeviceBudget(SOURCE, served["budget_s"])]
        cfg = small_config()
        r1 = SearchEngine(served["service"], budgets, cfg).run()
        r2 = SearchEngine(served["service"], budgets, cfg).run()
        assert r1.front_json() == r2.front_json()
        assert [s.to_json() for s in r1.stats] == [s.to_json() for s in r2.stats]
        assert len(r1.front) > 0
        assert r1.candidates_scored >= cfg.population_size

    def test_one_predict_batch_per_generation_per_device(self, served,
                                                         monkeypatch):
        svc = served["service"]
        budgets = [DeviceBudget(SOURCE, served["budget_s"])]
        cfg = small_config(seed=23)
        calls = {"n": 0}
        real = svc.predict_batch

        def counting(*a, **kw):
            calls["n"] += 1
            return real(*a, **kw)

        monkeypatch.setattr(svc, "predict_batch", counting)
        eng = SearchEngine(svc, budgets, cfg)
        rep = eng.run()
        gens_with_new = sum(1 for s in rep.stats if s.new_scored > 0)
        assert calls["n"] == gens_with_new * len(budgets)
        assert rep.predict_batch_calls == calls["n"]
        for s in rep.stats:
            assert s.predict_calls in (0, len(budgets))

    def test_front_members_meet_budget_and_are_nondominated(self, served):
        budgets = [DeviceBudget(SOURCE, served["budget_s"])]
        rep = SearchEngine(served["service"], budgets, small_config()).run()
        pts = [m.objectives for m in rep.front]
        for m in rep.front:
            assert m.latencies[budgets[0].key] <= budgets[0].budget_s
        for i in range(len(pts)):
            for j in range(len(pts)):
                if i != j:
                    assert not dominates(pts[i], pts[j])

    def test_checkpoint_resume_bit_equivalence(self, served, tmp_path):
        budgets = [DeviceBudget(SOURCE, served["budget_s"])]
        cfg = small_config(generations=6, seed=31)
        straight = SearchEngine(served["service"], budgets, cfg).run()

        path = str(tmp_path / "search.json")
        eng = SearchEngine(served["service"], budgets, cfg)
        for _ in range(3):
            eng.step()
        eng.save(path)
        resumed = SearchEngine.load(path, served["service"]).run()
        assert straight.front_json() == resumed.front_json()
        assert [s.to_json() for s in straight.stats] == \
            [s.to_json() for s in resumed.stats]
        # Saved-state JSON round-trips to an identical checkpoint.
        eng2 = SearchEngine.load(path, served["service"])
        path2 = str(tmp_path / "again.json")
        eng2.save(path2)
        assert json.load(open(path)) == json.load(open(path2))

    def test_report_verify_measures_front_only(self, served):
        budgets = [DeviceBudget(SOURCE, served["budget_s"])]
        rep = SearchEngine(served["service"], budgets, small_config()).run()
        verify_sess = CostModelProfileSession(seed=3)
        out = rep.verify(verify_sess)
        assert out["n_verified"] == len(rep.front) == verify_sess.measured_graphs
        assert np.isfinite(out["mape"])
        # Predicted-vs-measured: the bank was trained on this very cost
        # model, so front predictions track measurements.
        assert out["mape"] < 1.0
        # A device the search never scored has nothing to verify against.
        with pytest.raises(ValueError, match="not among the searched"):
            rep.verify(CostModelProfileSession(seed=3),
                       DeviceSetting("other", "int8", "op_by_op"))


class TestMultiDevice:
    @pytest.fixture(scope="class")
    def two_device(self, served):
        """Register a transfer-calibrated synthetic second device."""
        hub, store = served["hub"], served["store"]
        if hub.get(TARGET, "gbdt") is None:
            device = SyntheticDevice("sim", seed=7, noise=0.1,
                                     base_scale=3.0)
            tsess = ReplayProfileSession(store, device, SOURCE)
            TransferEngine(SOURCE, TARGET, family="gbdt", seed=0).adapt(
                store, hub, tsess, 24)
        svc = LatencyService(hub, default_setting=SOURCE, predictor="gbdt")
        return {**served, "service": svc}

    def test_scorer_filters_on_every_device(self, two_device):
        svc = two_device["service"]
        graphs = [sample_architecture(s, SPACE) for s in range(300, 316)]
        loose = LatencyScorer(svc, [DeviceBudget(SOURCE, 1e9),
                                    DeviceBudget(TARGET, 1e9)])
        lats = loose.score(graphs)
        assert set(lats) == {loose.budgets[0].key, loose.budgets[1].key}
        assert loose.feasible_mask(lats).all()
        # Tighten ONLY the second device to its median: some candidates
        # that pass device 1 must now fail the joint constraint.
        t_med = float(np.median(lats[DeviceBudget(TARGET, 0).key]))
        tight = LatencyScorer(svc, [DeviceBudget(SOURCE, 1e9),
                                    DeviceBudget(TARGET, t_med)])
        mask = tight.feasible_mask(lats)
        assert 0 < mask.sum() < len(graphs)
        viol = tight.violation(lats)
        assert (viol[~mask] > 0).all() and (viol[mask] == 0).all()

    def test_search_respects_both_budgets(self, two_device):
        svc = two_device["service"]
        # Budget the target device near the typical scaled latency so
        # the constraint actually bites.
        probe = [sample_architecture(s, SPACE) for s in range(400, 408)]
        t_lat = [r.e2e_s for r in svc.predict_batch(probe, TARGET)]
        budgets = [DeviceBudget(SOURCE, two_device["budget_s"]),
                   DeviceBudget(TARGET, float(np.median(t_lat)))]
        rep = SearchEngine(svc, budgets, small_config(seed=13)).run()
        assert len(rep.front) > 0
        for m in rep.front:
            for b in budgets:
                assert m.latencies[b.key] <= b.budget_s
        # The joint constraint filtered someone: the run saw infeasible
        # candidates (else the second budget was vacuous).
        assert any(s.feasible_new < s.new_scored for s in rep.stats)
        # Objectives span both devices + quality.
        assert len(rep.front[0].objectives) == 3


# ---------------------------------------------------------------------------
# Service-side satellites: auto backend recording + multi-setting queries
# ---------------------------------------------------------------------------

class TestServiceBackend:
    def test_small_batch_runs_numpy_and_is_recorded(self, served):
        svc = LatencyService(served["hub"], default_setting=SOURCE,
                             predictor="gbdt")
        assert svc.inference_backend == "auto"
        svc.predict_batch([sample_architecture(900, SPACE)])
        st = svc.stats()
        assert st["predict_batch_calls"] == 1
        assert st["backend_runs"].get("numpy", 0) > 0
        assert st["backend_runs"].get("jax", 0) == 0

    def test_auto_crosses_to_jax_at_threshold(self, served, monkeypatch):
        from repro.kernels.tree_gather import HAS_JAX
        if not HAS_JAX:
            pytest.skip("jax gather backend unavailable")
        import repro.core.predictors.flat as flat_mod
        monkeypatch.setattr(flat_mod, "AUTO_JAX_MIN_SLOTS", 1)
        svc = LatencyService(served["hub"], default_setting=SOURCE,
                             predictor="gbdt")
        reports = svc.predict_batch(
            [sample_architecture(s, SPACE) for s in (901, 902)])
        st = svc.stats()
        assert st["backend_runs"].get("jax", 0) > 0
        assert all(np.isfinite(r.e2e_s) and r.e2e_s > 0 for r in reports)

    def test_forced_numpy_backend(self, served):
        svc = LatencyService(served["hub"], default_setting=SOURCE,
                             predictor="gbdt", inference_backend="numpy")
        svc.predict_batch([sample_architecture(903, SPACE)])
        assert "jax" not in svc.stats()["backend_runs"]


class TestPredictMulti:
    def test_one_call_per_setting_and_matching_reports(self, served):
        hub = served["hub"]
        if hub.get(TARGET, "gbdt") is None:
            device = SyntheticDevice("sim", seed=7, noise=0.1, base_scale=3.0)
            tsess = ReplayProfileSession(served["store"], device, SOURCE)
            TransferEngine(SOURCE, TARGET, family="gbdt", seed=0).adapt(
                served["store"], hub, tsess, 24)
        svc = LatencyService(hub, default_setting=SOURCE, predictor="gbdt")
        graphs = [sample_architecture(s, SPACE) for s in (910, 911, 912)]
        multi = svc.predict_multi(graphs, [SOURCE, TARGET])
        assert svc.stats()["predict_batch_calls"] == 2
        assert set(multi) == {"float32/op_by_op", "sim:float32/op_by_op"}
        single = svc.predict_batch(graphs, TARGET)
        assert [r.e2e_s for r in multi["sim:float32/op_by_op"]] == \
            [r.e2e_s for r in single]

# ---------------------------------------------------------------------------
# Genotype families: elastic knobs, random-wired DAGs, golden fingerprints
# ---------------------------------------------------------------------------

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "randwired_fingerprints.json")


@pytest.fixture(scope="module")
def served_rw():
    """Service whose bank has seen random-wired op types (elementwise
    joins, concat/resize from encoder-decoder skeletons) — chains alone
    don't cover them."""
    golden = json.load(open(GOLDEN))
    rwc = RandomWiredConfig(**golden["rw_config"])
    store = ProfileStore()
    session = CostModelProfileSession(store=store, seed=3)
    graphs = synthetic_graphs(8, resolution=16)
    graphs += [decode_genotype(sample_random_wired(s, rwc), SPACE)
               for s in range(4)]
    for g in graphs:
        session.profile_graph(g, SOURCE)
    hub = PredictorHub()
    hub.train(store, SOURCE, "gbdt", hparams={"n_stages": 20}, min_samples=3)
    svc = LatencyService(hub, default_setting=SOURCE, predictor="gbdt")
    e2e = [store.get_arch(SOURCE, g.fingerprint()).e2e_s for g in graphs]
    return {"service": svc, "budget_s": float(np.median(e2e)), "rwc": rwc}


class TestGoldenFingerprints:
    """Differential decode: 200 seeded genotypes pinned by golden file.

    Any drift in the samplers, serialization, or decoders shows up as a
    digest/fingerprint mismatch against `tests/golden/`."""

    @pytest.fixture(scope="class")
    def golden(self):
        return json.load(open(GOLDEN))

    def test_random_wired_pinned(self, golden):
        rwc = RandomWiredConfig(**golden["rw_config"])
        space = NASSpaceConfig(**golden["space"])
        assert len(golden["random_wired"]) == 120
        for seed, (digest, fp) in sorted(golden["random_wired"].items(),
                                         key=lambda kv: int(kv[0])):
            gt = sample_random_wired(int(seed), rwc)
            assert gt.digest() == digest, f"rw seed {seed}: digest drift"
            clone = genotype_from_json(json.loads(json.dumps(gt.to_json())))
            assert clone == gt
            g = decode_genotype(clone, space)
            assert g.fingerprint() == fp, f"rw seed {seed}: decode drift"

    def test_elastic_pinned(self, golden):
        space = NASSpaceConfig(**golden["space"])
        assert len(golden["elastic"]) == 80
        for seed, (digest, fp) in sorted(golden["elastic"].items(),
                                         key=lambda kv: int(kv[0])):
            gt = sample_elastic_genotype(int(seed), space)
            assert gt.digest() == digest, f"elastic seed {seed}: digest drift"
            clone = genotype_from_json(json.loads(json.dumps(gt.to_json())))
            assert clone == gt and clone.family == "elastic"
            g = decode_genotype(clone, space)
            assert g.fingerprint() == fp, f"elastic seed {seed}: decode drift"

    def test_some_golden_graph_has_wide_fanout(self, golden):
        rwc = RandomWiredConfig(**golden["rw_config"])
        space = NASSpaceConfig(**golden["space"])
        best = 0
        for seed in range(20):
            g = decode_genotype(sample_random_wired(seed, rwc), space)
            fanout = {}
            for n in g.nodes:
                for t in n.inputs:
                    fanout[t] = fanout.get(t, 0) + 1
            best = max(best, max(fanout.values()))
        assert best >= 3


class TestElasticFamily:
    def test_shrink_grow_deterministic_and_single_knob(self):
        gt = sample_elastic_genotype(5, SPACE)
        assert gt.family == "elastic"
        for op in (shrink, grow, mutate_elastic):
            a = op(gt, np.random.default_rng(3), SPACE)
            b = op(gt, np.random.default_rng(3), SPACE)
            assert a == b and a.family == "elastic"
        small = shrink(gt, np.random.default_rng(3), SPACE)
        # Exactly one block differs, and within it at most one knob
        # moved (repair may not touch the others: same macro-skeleton).
        diff = [i for i, (x, y) in enumerate(zip(gt.blocks, small.blocks))
                if x != y]
        assert len(diff) <= 1
        if diff:
            x, y = gt.blocks[diff[0]], small.blocks[diff[0]]
            changed = sum(getattr(x, k) != getattr(y, k)
                          for k in ("kernel", "depth", "expansion", "out_c"))
            assert changed == 1

    def test_supernet_quality_needs_genotype(self):
        q = make_quality("supernet")
        assert getattr(q, "needs_genotype", False)
        gt = sample_elastic_genotype(2, SPACE)
        assert 0.0 < q(gt) <= 1.0
        with pytest.raises(TypeError, match="genotypes"):
            q(decode_genotype(gt, SPACE))

    def test_elastic_mutate_dispatch(self):
        gt = sample_elastic_genotype(9, SPACE)
        out = mutate(gt, np.random.default_rng(1), SPACE)
        assert out.family == "elastic"
        assert repair(out, SPACE) == out

    def test_elastic_search_deterministic_with_resume(self, served_rw,
                                                      tmp_path):
        budgets = [DeviceBudget(SOURCE, served_rw["budget_s"] * 4)]
        cfg = small_config(population_size=8, generations=4,
                           children_per_gen=6, seed=17, family="elastic",
                           quality="supernet")
        r1 = SearchEngine(served_rw["service"], budgets, cfg).run()
        r2 = SearchEngine(served_rw["service"], budgets, cfg).run()
        assert r1.front_json() == r2.front_json()
        assert len(r1.front) > 0
        for m in r1.front:
            gt = genotype_from_json(m.genotype)
            assert gt.family == "elastic"
        path = str(tmp_path / "elastic.json")
        eng = SearchEngine(served_rw["service"], budgets, cfg)
        eng.step(); eng.step()
        eng.save(path)
        resumed = SearchEngine.load(path, served_rw["service"]).run()
        assert resumed.front_json() == r1.front_json()


class TestRandomWiredFamily:
    def test_mutate_crossover_dispatch_and_family_guard(self):
        rng = np.random.default_rng(0)
        a = sample_random_wired(1, RandomWiredConfig(nodes_per_stage=5))
        b = sample_random_wired(2, RandomWiredConfig(nodes_per_stage=5))
        m = mutate(a, rng, SPACE)
        assert isinstance(m, RandomWiredGenotype)
        assert repair(m, SPACE) == m
        c = crossover(a, b, np.random.default_rng(4), SPACE)
        assert isinstance(c, RandomWiredGenotype)
        assert crossover(a, b, np.random.default_rng(4), SPACE) == c
        with pytest.raises(ValueError, match="families"):
            crossover(a, sample_genotype(0, SPACE), rng, SPACE)

    def test_random_wired_search_deterministic_with_resume(self, served_rw,
                                                           tmp_path):
        budgets = [DeviceBudget(SOURCE, served_rw["budget_s"] * 20)]
        cfg = small_config(population_size=8, generations=4,
                           children_per_gen=6, seed=29,
                           family="random_wired",
                           rw=served_rw["rwc"].to_json())
        r1 = SearchEngine(served_rw["service"], budgets, cfg).run()
        r2 = SearchEngine(served_rw["service"], budgets, cfg).run()
        assert r1.front_json() == r2.front_json()
        assert len(r1.front) > 0
        for m in r1.front:
            gt = genotype_from_json(m.genotype)
            assert isinstance(gt, RandomWiredGenotype)
        path = str(tmp_path / "rw.json")
        eng = SearchEngine(served_rw["service"], budgets, cfg)
        eng.step(); eng.step()
        eng.save(path)
        resumed = SearchEngine.load(path, served_rw["service"]).run()
        assert resumed.front_json() == r1.front_json()
        # Config JSON for a random-wired run round-trips its rw knobs.
        saved = json.load(open(path))
        assert saved["config"]["family"] == "random_wired"
